//! OrderBy: sort a table by one or more key columns (Table 2, "OrderBy").
//!
//! Produces a sorted index permutation then gathers once. Single numeric
//! key columns take a fast path (sort over primitive keys, no per-cell
//! dispatch); the general path uses a typed comparator chain. The sort
//! is stable so secondary orderings and repeated sorts compose.

use crate::table::rowcmp::{cmp_cells, KeyOrder};
use crate::table::{Array, Table};
use anyhow::Result;
use std::cmp::Ordering;

/// One sort key.
#[derive(Debug, Clone)]
pub struct SortKey {
    pub column: String,
    pub ascending: bool,
    /// Where nulls sort. Pandas default is "last" regardless of order.
    pub nulls_first: bool,
}

impl SortKey {
    pub fn asc(column: impl Into<String>) -> SortKey {
        SortKey { column: column.into(), ascending: true, nulls_first: false }
    }

    pub fn desc(column: impl Into<String>) -> SortKey {
        SortKey { column: column.into(), ascending: false, nulls_first: false }
    }

    /// The table-layer comparison spec for this key (shared with the
    /// distributed sample sort's splitter routing).
    pub fn order(&self) -> KeyOrder {
        KeyOrder { ascending: self.ascending, nulls_first: self.nulls_first }
    }
}

/// Compare rows `i`, `j` under one key (null placement + direction),
/// via the shared typed comparator in [`crate::table::rowcmp`].
#[inline]
fn cmp_key(col: &Array, key: &SortKey, i: usize, j: usize) -> Ordering {
    cmp_cells(col, i, col, j, key.order())
}

/// The permutation that sorts `table` by `keys` (stable).
pub fn sort_indices(table: &Table, keys: &[SortKey]) -> Result<Vec<usize>> {
    assert!(!keys.is_empty(), "sort: no keys");
    let cols: Vec<&Array> = keys
        .iter()
        .map(|k| table.column_by_name(&k.column))
        .collect::<Result<_>>()?;

    let mut idx: Vec<usize> = (0..table.num_rows()).collect();

    // Fast path: single fully-valid key of a cheap-to-order layout.
    // Descending sorts by the reversed key (NOT sort-then-reverse,
    // which would flip the relative order of equal keys and break the
    // stability contract).
    if keys.len() == 1 && cols[0].null_count() == 0 {
        match cols[0] {
            Array::Int64(v, _) => {
                if keys[0].ascending {
                    idx.sort_by_key(|&i| v[i]);
                } else {
                    idx.sort_by_key(|&i| std::cmp::Reverse(v[i]));
                }
                return Ok(idx);
            }
            // Dictionary-encoded strings sort in code space: one rank
            // table over the dictionary, then a primitive u32 sort —
            // string bytes are compared once per *distinct* value.
            Array::DictUtf8(d, _) => {
                let rank = d.sorted_ranks();
                if keys[0].ascending {
                    idx.sort_by_key(|&i| rank[d.codes[i] as usize]);
                } else {
                    idx.sort_by_key(|&i| std::cmp::Reverse(rank[d.codes[i] as usize]));
                }
                return Ok(idx);
            }
            // Plain strings: borrow slices directly, skipping the
            // per-cell validity + type dispatch of the general path.
            Array::Utf8(d, _) => {
                if keys[0].ascending {
                    idx.sort_by(|&a, &b| d.value(a).cmp(d.value(b)));
                } else {
                    idx.sort_by(|&a, &b| d.value(b).cmp(d.value(a)));
                }
                return Ok(idx);
            }
            _ => {}
        }
    }

    idx.sort_by(|&a, &b| {
        for (col, key) in cols.iter().zip(keys.iter()) {
            let o = cmp_key(col, key, a, b);
            if o != Ordering::Equal {
                return o;
            }
        }
        Ordering::Equal
    });
    Ok(idx)
}

/// Sort a table by `keys`.
pub fn sort(table: &Table, keys: &[SortKey]) -> Result<Table> {
    Ok(table.take(&sort_indices(table, keys)?))
}

/// Convenience: ascending sort by column names.
pub fn sort_by_columns(table: &Table, columns: &[&str]) -> Result<Table> {
    let keys: Vec<SortKey> = columns.iter().map(|c| SortKey::asc(*c)).collect();
    sort(table, &keys)
}

/// Check whether `table` is sorted under `keys` (used by distributed
/// sort's invariant tests).
pub fn is_sorted(table: &Table, keys: &[SortKey]) -> Result<bool> {
    let cols: Vec<&Array> = keys
        .iter()
        .map(|k| table.column_by_name(&k.column))
        .collect::<Result<_>>()?;
    for i in 1..table.num_rows() {
        for (col, key) in cols.iter().zip(keys.iter()) {
            match cmp_key(col, key, i - 1, i) {
                Ordering::Greater => return Ok(false),
                Ordering::Less => break,
                Ordering::Equal => continue,
            }
        }
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::table::Scalar;

    fn t() -> Table {
        Table::from_columns(vec![
            ("k", Array::from_opt_i64(vec![Some(3), Some(1), None, Some(1)])),
            ("v", Array::from_strs(&["c", "b", "n", "a"])),
        ])
        .unwrap()
    }

    #[test]
    fn single_key_asc_nulls_last() {
        let s = sort(&t(), &[SortKey::asc("k")]).unwrap();
        assert_eq!(s.cell(0, 0), Scalar::Int64(1));
        assert_eq!(s.cell(1, 0), Scalar::Int64(1));
        assert_eq!(s.cell(2, 0), Scalar::Int64(3));
        assert_eq!(s.cell(3, 0), Scalar::Null);
        // stability: the two k=1 rows keep input order (b before a)
        assert_eq!(s.cell(0, 1), Scalar::Utf8("b".into()));
        assert!(is_sorted(&s, &[SortKey::asc("k")]).unwrap());
    }

    #[test]
    fn desc_and_nulls_first() {
        let key = SortKey { column: "k".into(), ascending: false, nulls_first: true };
        let s = sort(&t(), std::slice::from_ref(&key)).unwrap();
        assert_eq!(s.cell(0, 0), Scalar::Null);
        assert_eq!(s.cell(1, 0), Scalar::Int64(3));
        assert!(is_sorted(&s, std::slice::from_ref(&key)).unwrap());
    }

    #[test]
    fn multi_key() {
        let s = sort(&t(), &[SortKey::asc("k"), SortKey::desc("v")]).unwrap();
        // k=1 group sorted by v desc: b then a
        assert_eq!(s.cell(0, 1), Scalar::Utf8("b".into()));
        assert_eq!(s.cell(1, 1), Scalar::Utf8("a".into()));
    }

    #[test]
    fn fast_path_matches_general() {
        let tbl = Table::from_columns(vec![
            ("k", Array::from_i64(vec![5, 3, 9, 3, 1])),
            ("tag", Array::from_strs(&["a", "b", "c", "d", "e"])),
        ])
        .unwrap();
        let fast = sort(&tbl, &[SortKey::asc("k")]).unwrap();
        // force general path via two keys where second never ties-breaks
        let gen = sort(&tbl, &[SortKey::asc("k"), SortKey::asc("k")]).unwrap();
        assert_eq!(fast, gen);
        let fast_desc = sort(&tbl, &[SortKey::desc("k")]).unwrap();
        assert!(is_sorted(&fast_desc, &[SortKey::desc("k")]).unwrap());
        // stability on the desc fast path: equal keys keep input order
        let gen_desc = sort(&tbl, &[SortKey::desc("k"), SortKey::desc("k")]).unwrap();
        assert_eq!(fast_desc, gen_desc, "desc fast path must stay stable");
        // desc order is 9,5,3,3,1; the tied 3s keep input order: b then d
        assert_eq!(fast_desc.cell(2, 1), Scalar::Utf8("b".into()));
        assert_eq!(fast_desc.cell(3, 1), Scalar::Utf8("d".into()));
    }

    #[test]
    fn string_fast_paths_match_general_and_stay_stable() {
        let plain = Table::from_columns(vec![
            ("s", Array::from_strs(&["m", "a", "m", "z", "a"])),
            ("tag", Array::from_i64(vec![0, 1, 2, 3, 4])),
        ])
        .unwrap();
        let dict = plain.dict_encode_columns();
        for asc in [true, false] {
            let key = SortKey { column: "s".into(), ascending: asc, nulls_first: false };
            // force the general comparator path with a redundant second key
            let general =
                sort_indices(&plain, &[key.clone(), SortKey::asc("tag")]).unwrap();
            assert_eq!(sort_indices(&plain, std::slice::from_ref(&key)).unwrap(), general);
            assert_eq!(sort_indices(&dict, std::slice::from_ref(&key)).unwrap(), general);
        }
        // stability: equal keys keep input order (asc → a@1 before a@4)
        let s = sort(&dict, &[SortKey::asc("s")]).unwrap();
        assert_eq!(s.cell(0, 1), Scalar::Int64(1));
        assert_eq!(s.cell(1, 1), Scalar::Int64(4));
    }

    #[test]
    fn float_keys_with_nan() {
        let tbl = Table::from_columns(vec![(
            "x",
            Array::from_f64(vec![2.0, f64::NAN, -1.0]),
        )])
        .unwrap();
        let s = sort(&tbl, &[SortKey::asc("x")]).unwrap();
        assert_eq!(s.cell(0, 0), Scalar::Float64(-1.0));
        assert_eq!(s.cell(1, 0), Scalar::Float64(2.0));
        // NaN sorts last under the canonical total order
        assert!(s.cell(2, 0).as_f64().unwrap().is_nan());
    }
}
