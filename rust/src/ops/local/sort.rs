//! OrderBy: sort a table by one or more key columns (Table 2, "OrderBy").
//!
//! Produces a sorted index permutation then gathers once. Single numeric
//! key columns take a fast path (sort over primitive keys, no per-cell
//! dispatch); the general path uses a typed comparator chain. The sort
//! is stable so secondary orderings and repeated sorts compose.

use crate::exec::morsel::{self, morsel_ranges, run_morsels, MemBudget, MorselConfig, SpillFile};
use crate::table::rowcmp::{cmp_cells, KeyOrder};
use crate::table::{Array, Table};
use anyhow::Result;
use std::cmp::Ordering;

/// One sort key.
#[derive(Debug, Clone)]
pub struct SortKey {
    pub column: String,
    pub ascending: bool,
    /// Where nulls sort. Pandas default is "last" regardless of order.
    pub nulls_first: bool,
}

impl SortKey {
    pub fn asc(column: impl Into<String>) -> SortKey {
        SortKey { column: column.into(), ascending: true, nulls_first: false }
    }

    pub fn desc(column: impl Into<String>) -> SortKey {
        SortKey { column: column.into(), ascending: false, nulls_first: false }
    }

    /// The table-layer comparison spec for this key (shared with the
    /// distributed sample sort's splitter routing).
    pub fn order(&self) -> KeyOrder {
        KeyOrder { ascending: self.ascending, nulls_first: self.nulls_first }
    }
}

/// Compare rows `i`, `j` under one key (null placement + direction),
/// via the shared typed comparator in [`crate::table::rowcmp`].
#[inline]
fn cmp_key(col: &Array, key: &SortKey, i: usize, j: usize) -> Ordering {
    cmp_cells(col, i, col, j, key.order())
}

/// The permutation that sorts `table` by `keys` (stable).
pub fn sort_indices(table: &Table, keys: &[SortKey]) -> Result<Vec<usize>> {
    assert!(!keys.is_empty(), "sort: no keys");
    let cols: Vec<&Array> = keys
        .iter()
        .map(|k| table.column_by_name(&k.column))
        .collect::<Result<_>>()?;

    let mut idx: Vec<usize> = (0..table.num_rows()).collect();

    // Fast path: single fully-valid key of a cheap-to-order layout.
    // Descending sorts by the reversed key (NOT sort-then-reverse,
    // which would flip the relative order of equal keys and break the
    // stability contract).
    if keys.len() == 1 && cols[0].null_count() == 0 {
        match cols[0] {
            Array::Int64(v, _) | Array::Timestamp(v, _) => {
                if keys[0].ascending {
                    idx.sort_by_key(|&i| v[i]);
                } else {
                    idx.sort_by_key(|&i| std::cmp::Reverse(v[i]));
                }
                return Ok(idx);
            }
            // Dictionary-encoded strings sort in code space: one rank
            // table over the dictionary, then a primitive u32 sort —
            // string bytes are compared once per *distinct* value.
            Array::DictUtf8(d, _) => {
                let rank = d.sorted_ranks();
                if keys[0].ascending {
                    idx.sort_by_key(|&i| rank[d.codes[i] as usize]);
                } else {
                    idx.sort_by_key(|&i| std::cmp::Reverse(rank[d.codes[i] as usize]));
                }
                return Ok(idx);
            }
            // Plain strings: borrow slices directly, skipping the
            // per-cell validity + type dispatch of the general path.
            Array::Utf8(d, _) => {
                if keys[0].ascending {
                    idx.sort_by(|&a, &b| d.value(a).cmp(d.value(b)));
                } else {
                    idx.sort_by(|&a, &b| d.value(b).cmp(d.value(a)));
                }
                return Ok(idx);
            }
            _ => {}
        }
    }

    idx.sort_by(|&a, &b| {
        for (col, key) in cols.iter().zip(keys.iter()) {
            let o = cmp_key(col, key, a, b);
            if o != Ordering::Equal {
                return o;
            }
        }
        Ordering::Equal
    });
    Ok(idx)
}

/// Sort a table by `keys`.
pub fn sort(table: &Table, keys: &[SortKey]) -> Result<Table> {
    Ok(table.take(&sort_indices(table, keys)?))
}

/// Morsel-driven run formation + merge: the permutation that sorts
/// `table` by `keys`, computed as per-range stable runs on the
/// work-stealing pool and k-way merged with ties going to the earlier
/// run. Because ranges are contiguous and ascending, "earlier run"
/// means "smaller input index", so the merged permutation is exactly
/// the global stable sort for any data and any morsel count. Under a
/// byte budget each run's key rows spill to disk as segmented canonical
/// IPC files (external merge: one resident segment per run). At the
/// defaults (one morsel, unlimited) this is a passthrough to
/// [`sort_indices`].
pub fn sort_indices_morsel(
    table: &Table,
    keys: &[SortKey],
    cfg: &MorselConfig,
    budget: &MemBudget,
) -> Result<Vec<usize>> {
    let nrows = table.num_rows();
    let count = cfg.morsel_count(nrows, table.nbytes());
    if count <= 1 && budget.is_unlimited() {
        return sort_indices(table, keys);
    }

    // Run formation: each range is slice-sorted by the same kernel the
    // whole-partition path uses (fast paths included — they agree with
    // the cmp_cells chain on the rows they accept), then offset back to
    // global indices.
    let ranges = morsel_ranges(nrows, count);
    let weights: Vec<usize> = ranges.iter().map(|&(_, len)| len).collect();
    let runs: Vec<Vec<usize>> = run_morsels(&weights, |m| {
        let (start, len) = ranges[m];
        let local = sort_indices(&table.slice(start, len), keys)?;
        Ok(local.into_iter().map(|i| i + start).collect())
    })?;

    let key_cols: Vec<&Array> = keys
        .iter()
        .map(|k| table.column_by_name(&k.column))
        .collect::<Result<_>>()?;

    if budget.is_unlimited() {
        // In-memory merge straight off the original key columns.
        let mut heads = vec![0usize; runs.len()];
        let mut out = Vec::with_capacity(nrows);
        loop {
            let mut best: Option<(usize, usize)> = None; // (run, global idx)
            for (r, run) in runs.iter().enumerate() {
                let Some(&cand) = run.get(heads[r]) else { continue };
                let better = match best {
                    None => true,
                    // tie → earlier run, i.e. keep `best`
                    Some((_, cur)) => cmp_runs(&key_cols, keys, cand, cur) == Ordering::Less,
                };
                if better {
                    best = Some((r, cand));
                }
            }
            let Some((r, idx)) = best else { break };
            out.push(idx);
            heads[r] += 1;
        }
        return Ok(out);
    }

    // External merge: spill each run's key rows (plus the global index)
    // as a chain of canonical-IPC segments sized so that one resident
    // segment per run fits the per-run budget share, then merge with
    // cursors over the resident segments.
    let limit = budget.limit().expect("limited branch");
    let mut cursors = Vec::with_capacity(runs.len());
    for run in &runs {
        cursors.push(RunCursor::spill(table, &key_cols, run, limit / runs.len().max(1))?);
    }
    let mut out = Vec::with_capacity(nrows);
    loop {
        let mut best: Option<usize> = None;
        for r in 0..cursors.len() {
            if cursors[r].resident.is_none() {
                continue;
            }
            let better = match best {
                None => true,
                Some(cur) => cmp_cursors(&cursors[r], &cursors[cur], keys) == Ordering::Less,
            };
            if better {
                best = Some(r);
            }
        }
        let Some(r) = best else { break };
        out.push(cursors[r].head_index());
        cursors[r].advance()?;
    }
    Ok(out)
}

/// Sort under the process-wide morsel/budget configuration; identical
/// output to [`sort`] for every configuration.
pub fn sort_morsel(table: &Table, keys: &[SortKey]) -> Result<Table> {
    let (cfg, budget) = morsel::current();
    Ok(table.take(&sort_indices_morsel(table, keys, &cfg, &budget)?))
}

/// Compare two rows of the original table under the key chain.
fn cmp_runs(key_cols: &[&Array], keys: &[SortKey], a: usize, b: usize) -> Ordering {
    for (col, key) in key_cols.iter().zip(keys.iter()) {
        let o = cmp_cells(col, a, col, b, key.order());
        if o != Ordering::Equal {
            return o;
        }
    }
    Ordering::Equal
}

/// Cursor over one spilled sort run: the run's key rows + global index
/// live in a chain of canonical-IPC segments; exactly one segment is
/// resident at a time.
struct RunCursor {
    segments: Vec<SpillFile>,
    next_segment: usize,
    resident: Option<Table>,
    row: usize,
}

impl RunCursor {
    /// Spill `run`'s key rows in segments of at most `share` bytes
    /// (estimated from the run's in-memory key bytes; always ≥ 1 row).
    fn spill(table: &Table, key_cols: &[&Array], run: &[usize], share: usize) -> Result<RunCursor> {
        // Key columns get positional names so a key column listed twice
        // (legal in a sort spec) cannot collide; the trailing column
        // carries the global row index through the merge.
        let mut arrays: Vec<Array> = key_cols.iter().map(|c| c.take(run)).collect();
        arrays.push(Array::from_i64(run.iter().map(|&i| i as i64).collect()));
        let names: Vec<String> = (0..key_cols.len())
            .map(|i| format!("__k{i}"))
            .chain(std::iter::once("__hptmt_idx".to_string()))
            .collect();
        let cols: Vec<(&str, Array)> =
            names.iter().map(|s| s.as_str()).zip(arrays).collect();
        let run_table = Table::from_columns(cols)?;

        let run_bytes = run_table.nbytes().max(1);
        let seg_rows = if run.is_empty() {
            1
        } else {
            ((run.len() as u128 * share.max(1) as u128) / run_bytes as u128).max(1) as usize
        };
        let mut segments = Vec::new();
        let mut start = 0;
        while start < run.len() {
            let len = seg_rows.min(run.len() - start);
            segments.push(SpillFile::write(&run_table.slice(start, len))?);
            start += len;
        }
        let mut cursor = RunCursor { segments, next_segment: 0, resident: None, row: 0 };
        cursor.load_next()?;
        Ok(cursor)
    }

    fn load_next(&mut self) -> Result<()> {
        self.resident = None;
        self.row = 0;
        if self.next_segment < self.segments.len() {
            let seg = self.segments[self.next_segment].read()?;
            morsel::note_state_bytes(seg.nbytes());
            self.resident = Some(seg);
            self.next_segment += 1;
        }
        Ok(())
    }

    fn head_index(&self) -> usize {
        let seg = self.resident.as_ref().expect("cursor exhausted");
        let idx_col = seg.column(seg.num_columns() - 1);
        idx_col.i64_values().expect("index column is Int64")[self.row] as usize
    }

    fn advance(&mut self) -> Result<()> {
        let rows = self.resident.as_ref().map_or(0, Table::num_rows);
        self.row += 1;
        if self.row >= rows {
            self.load_next()?;
        }
        Ok(())
    }
}

/// Compare the head rows of two spilled-run cursors under the key
/// chain. Segment tables carry the keys positionally (`__k{i}`), so the
/// comparison reads column `i` of each resident segment.
fn cmp_cursors(a: &RunCursor, b: &RunCursor, keys: &[SortKey]) -> Ordering {
    let ta = a.resident.as_ref().expect("cursor exhausted");
    let tb = b.resident.as_ref().expect("cursor exhausted");
    for (i, key) in keys.iter().enumerate() {
        let o = cmp_cells(ta.column(i), a.row, tb.column(i), b.row, key.order());
        if o != Ordering::Equal {
            return o;
        }
    }
    Ordering::Equal
}

/// Convenience: ascending sort by column names.
pub fn sort_by_columns(table: &Table, columns: &[&str]) -> Result<Table> {
    let keys: Vec<SortKey> = columns.iter().map(|c| SortKey::asc(*c)).collect();
    sort(table, &keys)
}

/// Check whether `table` is sorted under `keys` (used by distributed
/// sort's invariant tests).
pub fn is_sorted(table: &Table, keys: &[SortKey]) -> Result<bool> {
    let cols: Vec<&Array> = keys
        .iter()
        .map(|k| table.column_by_name(&k.column))
        .collect::<Result<_>>()?;
    for i in 1..table.num_rows() {
        for (col, key) in cols.iter().zip(keys.iter()) {
            match cmp_key(col, key, i - 1, i) {
                Ordering::Greater => return Ok(false),
                Ordering::Less => break,
                Ordering::Equal => continue,
            }
        }
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::table::Scalar;

    fn t() -> Table {
        Table::from_columns(vec![
            ("k", Array::from_opt_i64(vec![Some(3), Some(1), None, Some(1)])),
            ("v", Array::from_strs(&["c", "b", "n", "a"])),
        ])
        .unwrap()
    }

    #[test]
    fn single_key_asc_nulls_last() {
        let s = sort(&t(), &[SortKey::asc("k")]).unwrap();
        assert_eq!(s.cell(0, 0), Scalar::Int64(1));
        assert_eq!(s.cell(1, 0), Scalar::Int64(1));
        assert_eq!(s.cell(2, 0), Scalar::Int64(3));
        assert_eq!(s.cell(3, 0), Scalar::Null);
        // stability: the two k=1 rows keep input order (b before a)
        assert_eq!(s.cell(0, 1), Scalar::Utf8("b".into()));
        assert!(is_sorted(&s, &[SortKey::asc("k")]).unwrap());
    }

    #[test]
    fn desc_and_nulls_first() {
        let key = SortKey { column: "k".into(), ascending: false, nulls_first: true };
        let s = sort(&t(), std::slice::from_ref(&key)).unwrap();
        assert_eq!(s.cell(0, 0), Scalar::Null);
        assert_eq!(s.cell(1, 0), Scalar::Int64(3));
        assert!(is_sorted(&s, std::slice::from_ref(&key)).unwrap());
    }

    #[test]
    fn multi_key() {
        let s = sort(&t(), &[SortKey::asc("k"), SortKey::desc("v")]).unwrap();
        // k=1 group sorted by v desc: b then a
        assert_eq!(s.cell(0, 1), Scalar::Utf8("b".into()));
        assert_eq!(s.cell(1, 1), Scalar::Utf8("a".into()));
    }

    #[test]
    fn fast_path_matches_general() {
        let tbl = Table::from_columns(vec![
            ("k", Array::from_i64(vec![5, 3, 9, 3, 1])),
            ("tag", Array::from_strs(&["a", "b", "c", "d", "e"])),
        ])
        .unwrap();
        let fast = sort(&tbl, &[SortKey::asc("k")]).unwrap();
        // force general path via two keys where second never ties-breaks
        let gen = sort(&tbl, &[SortKey::asc("k"), SortKey::asc("k")]).unwrap();
        assert_eq!(fast, gen);
        let fast_desc = sort(&tbl, &[SortKey::desc("k")]).unwrap();
        assert!(is_sorted(&fast_desc, &[SortKey::desc("k")]).unwrap());
        // stability on the desc fast path: equal keys keep input order
        let gen_desc = sort(&tbl, &[SortKey::desc("k"), SortKey::desc("k")]).unwrap();
        assert_eq!(fast_desc, gen_desc, "desc fast path must stay stable");
        // desc order is 9,5,3,3,1; the tied 3s keep input order: b then d
        assert_eq!(fast_desc.cell(2, 1), Scalar::Utf8("b".into()));
        assert_eq!(fast_desc.cell(3, 1), Scalar::Utf8("d".into()));
    }

    #[test]
    fn string_fast_paths_match_general_and_stay_stable() {
        let plain = Table::from_columns(vec![
            ("s", Array::from_strs(&["m", "a", "m", "z", "a"])),
            ("tag", Array::from_i64(vec![0, 1, 2, 3, 4])),
        ])
        .unwrap();
        let dict = plain.dict_encode_columns();
        for asc in [true, false] {
            let key = SortKey { column: "s".into(), ascending: asc, nulls_first: false };
            // force the general comparator path with a redundant second key
            let general =
                sort_indices(&plain, &[key.clone(), SortKey::asc("tag")]).unwrap();
            assert_eq!(sort_indices(&plain, std::slice::from_ref(&key)).unwrap(), general);
            assert_eq!(sort_indices(&dict, std::slice::from_ref(&key)).unwrap(), general);
        }
        // stability: equal keys keep input order (asc → a@1 before a@4)
        let s = sort(&dict, &[SortKey::asc("s")]).unwrap();
        assert_eq!(s.cell(0, 1), Scalar::Int64(1));
        assert_eq!(s.cell(1, 1), Scalar::Int64(4));
    }

    #[test]
    fn float_keys_with_nan() {
        let tbl = Table::from_columns(vec![(
            "x",
            Array::from_f64(vec![2.0, f64::NAN, -1.0]),
        )])
        .unwrap();
        let s = sort(&tbl, &[SortKey::asc("x")]).unwrap();
        assert_eq!(s.cell(0, 0), Scalar::Float64(-1.0));
        assert_eq!(s.cell(1, 0), Scalar::Float64(2.0));
        // NaN sorts last under the canonical total order
        assert!(s.cell(2, 0).as_f64().unwrap().is_nan());
    }
}
