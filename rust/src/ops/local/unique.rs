//! Duplicate handling: `drop_duplicates` / distinct (Pandas analogues
//! used heavily by the UNOMT pipeline).

use super::groupby::group_ids;
use crate::table::Table;
use anyhow::Result;

/// Keep the first row of every distinct key combination.
///
/// `keys = None` deduplicates over all columns (Pandas
/// `drop_duplicates()` default).
pub fn drop_duplicates(table: &Table, keys: Option<&[&str]>) -> Result<Table> {
    let all_names;
    let keys: &[&str] = match keys {
        Some(k) => k,
        None => {
            all_names = table.schema().names();
            &all_names
        }
    };
    let (_, reps) = group_ids(table, keys)?;
    Ok(table.take(&reps))
}

/// Distinct values of the key columns only (SQL `SELECT DISTINCT k...`).
pub fn unique(table: &Table, keys: &[&str]) -> Result<Table> {
    drop_duplicates(&table.select_columns(keys)?, None)
}

/// Count of distinct key combinations.
pub fn n_unique(table: &Table, keys: &[&str]) -> Result<usize> {
    let (_, reps) = group_ids(table, keys)?;
    Ok(reps.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::table::{Array, Scalar};

    fn t() -> Table {
        Table::from_columns(vec![
            ("k", Array::from_opt_i64(vec![Some(1), Some(2), Some(1), None, None])),
            ("v", Array::from_strs(&["a", "b", "c", "d", "d"])),
        ])
        .unwrap()
    }

    #[test]
    fn dedup_on_key() {
        let d = drop_duplicates(&t(), Some(&["k"])).unwrap();
        assert_eq!(d.num_rows(), 3); // 1, 2, null
        assert_eq!(d.cell(0, 1), Scalar::Utf8("a".into())); // first kept
    }

    #[test]
    fn dedup_all_columns() {
        let d = drop_duplicates(&t(), None).unwrap();
        assert_eq!(d.num_rows(), 4); // only (null, "d") duplicated
    }

    #[test]
    fn unique_projects() {
        let u = unique(&t(), &["k"]).unwrap();
        assert_eq!(u.num_columns(), 1);
        assert_eq!(u.num_rows(), 3);
        assert_eq!(n_unique(&t(), &["k"]).unwrap(), 3);
        assert_eq!(n_unique(&t(), &["k", "v"]).unwrap(), 4);
    }
}
