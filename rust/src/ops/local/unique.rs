//! Duplicate handling: `drop_duplicates` / distinct (Pandas analogues
//! used heavily by the UNOMT pipeline).

use super::groupby::group_ids;
use crate::exec::morsel::{self, par_hash_columns, MemBudget, MorselConfig, SpillFile};
use crate::table::{Array, Table};
use anyhow::Result;

/// Keep the first row of every distinct key combination.
///
/// `keys = None` deduplicates over all columns (Pandas
/// `drop_duplicates()` default).
pub fn drop_duplicates(table: &Table, keys: Option<&[&str]>) -> Result<Table> {
    let all_names;
    let keys: &[&str] = match keys {
        Some(k) => k,
        None => {
            all_names = table.schema().names();
            &all_names
        }
    };
    let (cfg, budget) = morsel::current();
    let reps = dedup_reps(table, keys, &cfg, &budget)?;
    Ok(table.take(&reps))
}

/// Representative (first-occurrence) row indices of the distinct key
/// combinations, ascending — exactly the `reps` that
/// [`group_ids`] produces, but with an over-budget hash state computed
/// partition-at-a-time through spill. Equal rows hash equal, so every
/// key class lands in one hash partition; within a partition rows keep
/// ascending original order, so the per-partition first occurrence is
/// the class's global minimum index, and the sorted union of partition
/// reps equals the whole-table reps (which are strictly increasing by
/// construction) for any data.
pub fn dedup_reps(
    table: &Table,
    keys: &[&str],
    cfg: &MorselConfig,
    budget: &MemBudget,
) -> Result<Vec<usize>> {
    let kcols: Vec<&Array> = keys
        .iter()
        .map(|c| table.column_by_name(c))
        .collect::<Result<_>>()?;
    let kbytes: usize = kcols.iter().map(|c| c.nbytes()).sum();
    if !budget.exceeded_by(kbytes) {
        let (_, reps) = group_ids(table, keys)?;
        return Ok(reps);
    }

    let limit = budget.limit().expect("limited branch");
    // 2x headroom: partition sizing is average-based, and the staged
    // table carries the extra index column — hash skew or fat rows must
    // not push a single resident partition past the budget.
    let parts = kbytes.div_ceil(limit.max(1)).saturating_mul(2).clamp(2, 64);
    let h = par_hash_columns(&kcols, cfg);
    let knames: Vec<String> = (0..kcols.len()).map(|i| format!("__k{i}")).collect();
    let mut reps = Vec::new();
    for part in 0..parts {
        let rows: Vec<usize> =
            (0..table.num_rows()).filter(|&i| h[i] as usize % parts == part).collect();
        if rows.is_empty() {
            continue;
        }
        // Stage the partition's key rows (plus original index) through
        // a spill file so only one partition of hash state is resident.
        let mut arrays: Vec<Array> = kcols.iter().map(|c| c.take(&rows)).collect();
        arrays.push(Array::from_i64(rows.iter().map(|&i| i as i64).collect()));
        let cols: Vec<(&str, Array)> = knames
            .iter()
            .map(|s| s.as_str())
            .chain(std::iter::once("__hptmt_idx"))
            .zip(arrays)
            .collect();
        let staged = SpillFile::write(&Table::from_columns(cols)?)?;
        let rd = staged.read()?;
        morsel::note_state_bytes(rd.nbytes());
        let krefs: Vec<&str> = knames.iter().map(|s| s.as_str()).collect();
        let (_, preps) = group_ids(&rd, &krefs)?;
        let idx = rd
            .column(rd.num_columns() - 1)
            .i64_values()
            .expect("index column is Int64");
        reps.extend(preps.iter().map(|&r| idx[r] as usize));
    }
    reps.sort_unstable();
    Ok(reps)
}

/// Distinct values of the key columns only (SQL `SELECT DISTINCT k...`).
pub fn unique(table: &Table, keys: &[&str]) -> Result<Table> {
    drop_duplicates(&table.select_columns(keys)?, None)
}

/// Count of distinct key combinations.
pub fn n_unique(table: &Table, keys: &[&str]) -> Result<usize> {
    let (_, reps) = group_ids(table, keys)?;
    Ok(reps.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::table::{Array, Scalar};

    fn t() -> Table {
        Table::from_columns(vec![
            ("k", Array::from_opt_i64(vec![Some(1), Some(2), Some(1), None, None])),
            ("v", Array::from_strs(&["a", "b", "c", "d", "d"])),
        ])
        .unwrap()
    }

    #[test]
    fn dedup_on_key() {
        let d = drop_duplicates(&t(), Some(&["k"])).unwrap();
        assert_eq!(d.num_rows(), 3); // 1, 2, null
        assert_eq!(d.cell(0, 1), Scalar::Utf8("a".into())); // first kept
    }

    #[test]
    fn dedup_all_columns() {
        let d = drop_duplicates(&t(), None).unwrap();
        assert_eq!(d.num_rows(), 4); // only (null, "d") duplicated
    }

    #[test]
    fn unique_projects() {
        let u = unique(&t(), &["k"]).unwrap();
        assert_eq!(u.num_columns(), 1);
        assert_eq!(u.num_rows(), 3);
        assert_eq!(n_unique(&t(), &["k"]).unwrap(), 3);
        assert_eq!(n_unique(&t(), &["k", "v"]).unwrap(), 4);
    }
}
