//! Map / apply: element-wise column transforms (the UNOMT pipeline's
//! drug-id cleanup `map` step, plus general numeric transforms).

use crate::table::{Array, Bitmap, DataType, Table};
use anyhow::{bail, Result};

/// Apply a string→string function to a Utf8 column (nulls pass through).
///
/// Dictionary-encoded inputs are accepted, but the output is always
/// plain `Utf8`: mapped values need not be low-cardinality, and `f` is
/// deliberately called once per *row* (not per dictionary entry — a
/// stateful `FnMut` would otherwise observe a different call sequence
/// than on the plain twin, breaking encoding invariance).
pub fn map_utf8<F: FnMut(&str) -> String>(col: &Array, mut f: F) -> Result<Array> {
    if col.data_type() != DataType::Utf8 {
        bail!("map_utf8 on {} column", col.data_type())
    }
    let mut out = crate::table::array::Utf8Data::empty();
    for i in 0..col.len() {
        if col.is_valid(i) {
            out.push(&f(col.str_at(i).unwrap_or("")));
        } else {
            out.push("");
        }
    }
    Ok(Array::Utf8(out, col.validity().cloned()))
}

/// Apply an f64→f64 function to a numeric column (ints widen to float;
/// nulls pass through).
pub fn map_f64<F: FnMut(f64) -> f64>(col: &Array, mut f: F) -> Result<Array> {
    if !col.data_type().is_numeric() {
        bail!("map_f64 on {} column", col.data_type());
    }
    let out: Vec<f64> = (0..col.len())
        .map(|i| col.f64_at(i).map(&mut f).unwrap_or(0.0))
        .collect();
    Ok(Array::Float64(out, col.validity().cloned()))
}

/// Apply an i64→i64 function to an Int64 column.
pub fn map_i64<F: FnMut(i64) -> i64>(col: &Array, mut f: F) -> Result<Array> {
    let Some(v) = col.i64_values() else {
        bail!("map_i64 on {} column", col.data_type())
    };
    let out: Vec<i64> = v.iter().map(|&x| f(x)).collect();
    Ok(Array::Int64(out, col.validity().cloned()))
}

/// Replace one column with a mapped version (Pandas
/// `df[col] = df[col].map(f)`).
pub fn map_column_utf8<F: FnMut(&str) -> String>(
    table: &Table,
    column: &str,
    f: F,
) -> Result<Table> {
    let col = table.column_by_name(column)?;
    table.with_column(column, map_utf8(col, f)?)
}

/// Numeric in-place map over a column.
pub fn map_column_f64<F: FnMut(f64) -> f64>(table: &Table, column: &str, f: F) -> Result<Table> {
    let col = table.column_by_name(column)?;
    table.with_column(column, map_f64(col, f)?)
}

/// Strip a set of characters anywhere in the string (UNOMT drug-id
/// symbol cleanup: `"NSC.123" → "NSC123"`).
pub fn strip_chars(col: &Array, chars: &[char]) -> Result<Array> {
    map_utf8(col, |s| s.chars().filter(|c| !chars.contains(c)).collect())
}

/// Min-max scale numeric columns to [0, 1] (the Scikit-learn
/// `MinMaxScaler` role in the UNOMT pipeline). Constant columns map
/// to 0. Returns the scaled table plus per-column (min, max).
pub fn min_max_scale(table: &Table, columns: &[&str]) -> Result<(Table, Vec<(f64, f64)>)> {
    let mut out = table.clone();
    let mut ranges = Vec::with_capacity(columns.len());
    for c in columns {
        let col = table.column_by_name(c)?;
        if !col.data_type().is_numeric() {
            bail!("min_max_scale: column {c:?} is {}", col.data_type());
        }
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for i in 0..col.len() {
            if let Some(x) = col.f64_at(i) {
                lo = lo.min(x);
                hi = hi.max(x);
            }
        }
        if !lo.is_finite() {
            // all-null column
            lo = 0.0;
            hi = 0.0;
        }
        let span = hi - lo;
        let scaled = map_f64(col, |x| if span > 0.0 { (x - lo) / span } else { 0.0 })?;
        out = out.with_column(c, scaled)?;
        ranges.push((lo, hi));
    }
    Ok((out, ranges))
}

/// Standard-score scale (x-mean)/std over numeric columns (the
/// Scikit-learn `StandardScaler` role). Returns per-column (mean, std).
pub fn standard_scale(table: &Table, columns: &[&str]) -> Result<(Table, Vec<(f64, f64)>)> {
    let mut out = table.clone();
    let mut stats = Vec::with_capacity(columns.len());
    for c in columns {
        let col = table.column_by_name(c)?;
        if !col.data_type().is_numeric() {
            bail!("standard_scale: column {c:?} is {}", col.data_type());
        }
        let (mut sum, mut sumsq, mut n) = (0.0, 0.0, 0u64);
        for i in 0..col.len() {
            if let Some(x) = col.f64_at(i) {
                sum += x;
                sumsq += x * x;
                n += 1;
            }
        }
        let mean = if n > 0 { sum / n as f64 } else { 0.0 };
        let var = if n > 0 { (sumsq / n as f64 - mean * mean).max(0.0) } else { 0.0 };
        let std = var.sqrt();
        let scaled = map_f64(col, |x| if std > 0.0 { (x - mean) / std } else { 0.0 })?;
        out = out.with_column(c, scaled)?;
        stats.push((mean, std));
    }
    Ok((out, stats))
}

/// Build a boolean column from a per-row predicate (helper for bespoke
/// conditions; result has no nulls).
pub fn build_mask<F: FnMut(usize) -> bool>(nrows: usize, mut f: F) -> Array {
    Array::Bool((0..nrows).map(|i| f(i)).collect(), None)
}

/// Null-safe equality mask between two columns of the same type.
pub fn eq_mask(a: &Array, b: &Array) -> Result<Array> {
    if a.len() != b.len() {
        bail!("eq_mask: length mismatch");
    }
    let vals: Vec<bool> = (0..a.len())
        .map(|i| crate::table::rowhash::cell_eq(a, i, b, i))
        .collect();
    let _ = Bitmap::new_valid(0); // keep Bitmap import for future use
    Ok(Array::Bool(vals, None))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::table::Scalar;

    #[test]
    fn utf8_map_preserves_nulls() {
        let col = Array::from_opt_strs(vec![Some("NSC.123"), None, Some("A-B")]);
        let out = strip_chars(&col, &['.', '-']).unwrap();
        assert_eq!(out.get(0), Scalar::Utf8("NSC123".into()));
        assert_eq!(out.get(1), Scalar::Null);
        assert_eq!(out.get(2), Scalar::Utf8("AB".into()));
    }

    #[test]
    fn dict_map_yields_plain_identical_to_plain_map() {
        let plain = Array::from_opt_strs(vec![Some("a.b"), None, Some("c.d")]);
        let dict = plain.clone().dict_encode();
        let from_dict = strip_chars(&dict, &['.']).unwrap();
        let from_plain = strip_chars(&plain, &['.']).unwrap();
        assert!(!from_dict.is_dict(), "map output must be plain");
        assert_eq!(from_dict, from_plain);
    }

    #[test]
    fn numeric_maps() {
        let col = Array::from_opt_i64(vec![Some(2), None]);
        let f = map_f64(&col, |x| x * 10.0).unwrap();
        assert_eq!(f.get(0), Scalar::Float64(20.0));
        assert_eq!(f.get(1), Scalar::Null);
        let i = map_i64(&Array::from_i64(vec![1, 2]), |x| x + 1).unwrap();
        assert_eq!(i.i64_values().unwrap(), &[2, 3]);
        assert!(map_i64(&Array::from_f64(vec![1.0]), |x| x).is_err());
    }

    #[test]
    fn min_max_scaling() {
        let t = Table::from_columns(vec![
            ("x", Array::from_f64(vec![0.0, 5.0, 10.0])),
            ("c", Array::from_f64(vec![3.0, 3.0, 3.0])),
        ])
        .unwrap();
        let (s, ranges) = min_max_scale(&t, &["x", "c"]).unwrap();
        assert_eq!(s.cell(1, 0), Scalar::Float64(0.5));
        assert_eq!(s.cell(0, 1), Scalar::Float64(0.0)); // constant column
        assert_eq!(ranges[0], (0.0, 10.0));
    }

    #[test]
    fn standard_scaling() {
        let t = Table::from_columns(vec![("x", Array::from_f64(vec![1.0, 3.0]))]).unwrap();
        let (s, stats) = standard_scale(&t, &["x"]).unwrap();
        assert_eq!(stats[0].0, 2.0);
        assert_eq!(s.cell(0, 0), Scalar::Float64(-1.0));
        assert_eq!(s.cell(1, 0), Scalar::Float64(1.0));
    }

    #[test]
    fn table_level_map() {
        let t = Table::from_columns(vec![("id", Array::from_strs(&["x.1", "y.2"]))]).unwrap();
        let m = map_column_utf8(&t, "id", |s| s.replace('.', "")).unwrap();
        assert_eq!(m.cell(1, 0), Scalar::Utf8("y2".into()));
    }

    #[test]
    fn eq_masks() {
        let a = Array::from_opt_i64(vec![Some(1), None, Some(3)]);
        let b = Array::from_opt_i64(vec![Some(1), None, Some(4)]);
        let m = eq_mask(&a, &b).unwrap();
        assert_eq!(m.bool_values().unwrap(), &[true, true, false]);
    }
}
