//! Local (single-rank) operators — the paper's Table 2 taxonomy.
//!
//! | Paper operator    | Here |
//! |-------------------|------|
//! | Select            | [`select::filter_cmp`], [`select::filter_mask`] |
//! | Project           | [`crate::table::Table::select_columns`] / [`crate::table::Table::project`] |
//! | Union             | [`setops::union`], [`setops::union_all`] |
//! | Cartesian Product | [`setops::cartesian`] |
//! | Difference        | [`setops::difference`] |
//! | Intersect         | [`setops::intersect`] |
//! | Join (L/R/F/I)    | [`join::join`] |
//! | OrderBy           | [`sort::sort`] |
//! | Aggregate         | [`groupby::aggregate`] |
//! | GroupBy           | [`groupby::groupby_aggregate`] |
//!
//! Plus the Pandas-style operators the UNOMT application needs:
//! `drop_duplicates`/`unique`, `isin`, `map`, `astype` (cast),
//! `dropna`/`fillna`/`isnull`, sampling and scaling.

pub mod cast;
#[cfg(test)]
mod proptests;
pub mod groupby;
pub mod isin;
pub mod join;
pub mod map;
pub mod missing;
pub mod sample;
pub mod select;
pub mod setops;
pub mod sort;
pub mod unique;
pub mod window;

pub use cast::{cast, cast_columns, to_numeric_table};
pub use groupby::{aggregate, groupby_aggregate, Agg, AggSpec, PartialAggPlan};
pub use isin::{filter_isin, filter_not_in, isin_mask};
pub use join::{inner_join, join, JoinAlgorithm, JoinType};
pub use map::{map_column_f64, map_column_utf8, min_max_scale, standard_scale, strip_chars};
pub use missing::{dropna, fillna, isnull_mask, notnull_mask, DropNaHow};
pub use sample::{sample, sample_frac, shuffle, train_test_split};
pub use select::{filter_cmp, filter_mask, Cmp};
pub use setops::{cartesian, difference, intersect, union, union_all};
pub use sort::{is_sorted, sort, sort_by_columns, SortKey};
pub use unique::{drop_duplicates, n_unique, unique};
pub use window::{
    rolling, windowed_groupby, windowed_groupby_stream, with_rolling, Eviction, RollAgg,
    SegmentRing, WindowSpec, WindowUnit,
};
