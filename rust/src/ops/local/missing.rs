//! Missing-data handling: `isnull`, `notnull`, `dropna`, `fillna`
//! (all used by the UNOMT feature-engineering stages).

use crate::table::{Array, Scalar, Table};
use anyhow::{bail, Result};

/// Boolean mask of nulls in a column (`df[col].isnull()`).
pub fn isnull_mask(col: &Array) -> Array {
    Array::Bool((0..col.len()).map(|i| col.is_null(i)).collect(), None)
}

/// Boolean mask of non-nulls (`df[col].notnull()`).
pub fn notnull_mask(col: &Array) -> Array {
    Array::Bool((0..col.len()).map(|i| col.is_valid(i)).collect(), None)
}

/// How [`dropna`] decides to drop a row.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DropNaHow {
    /// Drop when ANY considered column is null (Pandas default).
    Any,
    /// Drop only when ALL considered columns are null.
    All,
}

/// Drop rows with nulls in the given columns (None = all columns).
pub fn dropna(table: &Table, subset: Option<&[&str]>, how: DropNaHow) -> Result<Table> {
    let cols: Vec<&Array> = match subset {
        Some(names) => names
            .iter()
            .map(|n| table.column_by_name(n))
            .collect::<Result<_>>()?,
        None => table.columns().iter().collect(),
    };
    if cols.is_empty() {
        bail!("dropna: no columns to consider");
    }
    let idx: Vec<usize> = (0..table.num_rows())
        .filter(|&i| match how {
            DropNaHow::Any => cols.iter().all(|c| c.is_valid(i)),
            DropNaHow::All => cols.iter().any(|c| c.is_valid(i)),
        })
        .collect();
    Ok(table.take(&idx))
}

/// Replace nulls in one column with a scalar.
pub fn fillna_column(col: &Array, fill: &Scalar) -> Result<Array> {
    if col.null_count() == 0 {
        return Ok(col.clone());
    }
    use crate::table::ArrayBuilder;
    let mut b = ArrayBuilder::with_capacity(col.data_type(), col.len());
    for i in 0..col.len() {
        if col.is_valid(i) {
            b.push_from(col, i);
        } else {
            b.push_scalar(fill)?;
        }
    }
    Ok(b.finish())
}

/// Fill nulls in the named columns of a table.
pub fn fillna(table: &Table, fills: &[(&str, Scalar)]) -> Result<Table> {
    let mut out = table.clone();
    for (name, fill) in fills {
        let col = out.column_by_name(name)?;
        out = out.with_column(name, fillna_column(col, fill)?)?;
    }
    Ok(out)
}

/// Count of nulls per column, in schema order.
pub fn null_counts(table: &Table) -> Vec<(String, usize)> {
    table
        .schema()
        .fields()
        .iter()
        .zip(table.columns())
        .map(|(f, c)| (f.name.clone(), c.null_count()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> Table {
        Table::from_columns(vec![
            ("a", Array::from_opt_i64(vec![Some(1), None, None, Some(4)])),
            ("b", Array::from_opt_strs(vec![Some("x"), Some("y"), None, None])),
        ])
        .unwrap()
    }

    #[test]
    fn masks() {
        let m = isnull_mask(t().column(0));
        assert_eq!(m.bool_values().unwrap(), &[false, true, true, false]);
        let n = notnull_mask(t().column(0));
        assert_eq!(n.bool_values().unwrap(), &[true, false, false, true]);
    }

    #[test]
    fn dropna_any_all() {
        let any = dropna(&t(), None, DropNaHow::Any).unwrap();
        assert_eq!(any.num_rows(), 1);
        let all = dropna(&t(), None, DropNaHow::All).unwrap();
        assert_eq!(all.num_rows(), 3); // only row 2 (both null) dropped
        let sub = dropna(&t(), Some(&["a"]), DropNaHow::Any).unwrap();
        assert_eq!(sub.num_rows(), 2);
    }

    #[test]
    fn fill_values() {
        let f = fillna(&t(), &[("a", Scalar::Int64(0)), ("b", Scalar::Utf8("?".into()))]).unwrap();
        assert_eq!(f.column(0).null_count(), 0);
        assert_eq!(f.cell(1, 0), Scalar::Int64(0));
        assert_eq!(f.cell(3, 1), Scalar::Utf8("?".into()));
        // type mismatch rejected
        assert!(fillna(&t(), &[("a", Scalar::Utf8("no".into()))]).is_err());
    }

    #[test]
    fn counts() {
        let c = null_counts(&t());
        assert_eq!(c, vec![("a".to_string(), 2), ("b".to_string(), 2)]);
    }
}
