//! `isin`: membership mask of a column against a set of values
//! (the UNOMT pipeline's drug/RNA filtering step, Fig 11).

use crate::table::rowhash::{cell_eq, hash_columns};
use crate::table::{Array, Table};
use anyhow::Result;
use std::collections::HashMap;

/// Boolean mask: `mask[i] = column[i] ∈ values`. Null cells yield false
/// (Pandas semantics).
pub fn isin_mask(column: &Array, values: &Array) -> Vec<bool> {
    let vh = hash_columns(&[values]);
    let mut set: HashMap<u64, Vec<u32>> = HashMap::with_capacity(values.len());
    for (j, &h) in vh.iter().enumerate() {
        if values.is_valid(j) {
            set.entry(h).or_default().push(j as u32);
        }
    }
    let ch = hash_columns(&[column]);
    (0..column.len())
        .map(|i| {
            column.is_valid(i)
                && set.get(&ch[i]).is_some_and(|cands| {
                    cands.iter().any(|&j| cell_eq(column, i, values, j as usize))
                })
        })
        .collect()
}

/// Filter `table` to rows whose `column` value appears in `values`.
pub fn filter_isin(table: &Table, column: &str, values: &Array) -> Result<Table> {
    let col = table.column_by_name(column)?;
    let mask = isin_mask(col, values);
    let idx: Vec<usize> = mask
        .iter()
        .enumerate()
        .filter_map(|(i, &m)| if m { Some(i) } else { None })
        .collect();
    Ok(table.take(&idx))
}

/// Filter to rows whose `column` value does NOT appear in `values`.
pub fn filter_not_in(table: &Table, column: &str, values: &Array) -> Result<Table> {
    let col = table.column_by_name(column)?;
    let mask = isin_mask(col, values);
    let idx: Vec<usize> = mask
        .iter()
        .enumerate()
        .filter_map(|(i, &m)| if !m { Some(i) } else { None })
        .collect();
    Ok(table.take(&idx))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::table::Scalar;

    #[test]
    fn int_membership() {
        let col = Array::from_opt_i64(vec![Some(1), Some(2), None, Some(4)]);
        let vals = Array::from_i64(vec![2, 4, 99]);
        assert_eq!(isin_mask(&col, &vals), vec![false, true, false, true]);
    }

    #[test]
    fn string_membership() {
        let col = Array::from_strs(&["a", "b", "c"]);
        let vals = Array::from_strs(&["c", "a"]);
        assert_eq!(isin_mask(&col, &vals), vec![true, false, true]);
    }

    #[test]
    fn null_values_in_set_ignored() {
        let col = Array::from_opt_i64(vec![None, Some(1)]);
        let vals = Array::from_opt_i64(vec![None, Some(1)]);
        // null ∈ set is false even when the set contains null (Pandas)
        assert_eq!(isin_mask(&col, &vals), vec![false, true]);
    }

    #[test]
    fn table_filters() {
        let t = Table::from_columns(vec![
            ("id", Array::from_strs(&["d1", "d2", "d3"])),
            ("x", Array::from_i64(vec![1, 2, 3])),
        ])
        .unwrap();
        let keep = Array::from_strs(&["d3", "d1"]);
        let f = filter_isin(&t, "id", &keep).unwrap();
        assert_eq!(f.num_rows(), 2);
        assert_eq!(f.cell(0, 0), Scalar::Utf8("d1".into()));
        let n = filter_not_in(&t, "id", &keep).unwrap();
        assert_eq!(n.num_rows(), 1);
        assert_eq!(n.cell(0, 0), Scalar::Utf8("d2".into()));
    }
}
