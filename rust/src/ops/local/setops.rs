//! Relational set operators over union-compatible tables (Table 2:
//! Union, Intersect, Difference, Cartesian Product).
//!
//! All three set operators use bag-to-set semantics like SQL's
//! UNION/INTERSECT/EXCEPT: results are distinct. `union_all` keeps
//! duplicates (SQL UNION ALL).

use super::unique::drop_duplicates;
use crate::exec::morsel::{self, for_each_budgeted_chunk, par_hash_columns};
use crate::table::rowhash::{hash_columns, rows_eq};
use crate::table::{Array, Table};
use anyhow::{bail, Result};
use std::collections::HashMap;

/// Strict union compatibility: column names AND types must match
/// positionally. Positional type equality alone would silently zip
/// unrelated columns together (e.g. after a rename); the set operators
/// reject that. Shared with `ops::dist::setops`, which must fail on
/// every rank *before* any communication.
pub fn check_union_compatible(a: &Table, b: &Table) -> Result<()> {
    if !a.schema().union_compatible(b.schema()) {
        bail!(
            "set op: union-incompatible schemas {} vs {} (column names and types must match \
             positionally)",
            a.schema(),
            b.schema()
        );
    }
    Ok(())
}

/// UNION ALL: vertical concatenation.
pub fn union_all(a: &Table, b: &Table) -> Result<Table> {
    check_union_compatible(a, b)?;
    Table::concat_tables(&[a, b])
}

/// UNION: concatenation with duplicates removed.
pub fn union(a: &Table, b: &Table) -> Result<Table> {
    drop_duplicates(&union_all(a, b)?, None)
}

/// Build a row-set over all columns of `t`: hash → row indices.
fn row_set(t: &Table) -> (Vec<&Array>, Vec<u64>, HashMap<u64, Vec<u32>>) {
    let cols: Vec<&Array> = t.columns().iter().collect();
    let hashes = hash_columns(&cols);
    let mut set: HashMap<u64, Vec<u32>> = HashMap::with_capacity(t.num_rows());
    for (i, &h) in hashes.iter().enumerate() {
        set.entry(h).or_default().push(i as u32);
    }
    (cols, hashes, set)
}

/// Per-row membership of `da`'s rows in `b`, with `b`'s hash state
/// staged through budget-sized chunks: each chunk builds its own
/// row-set and OR-marks the mask. Membership is a per-row predicate
/// over values, so chunked probing returns exactly the whole-table
/// mask; morsel-parallel hashing of `da` changes nothing (hashes are
/// per-row value functions).
fn membership_mask(da: &Table, b: &Table) -> Result<Vec<bool>> {
    let (cfg, budget) = morsel::current();
    let acols: Vec<&Array> = da.columns().iter().collect();
    let ah = par_hash_columns(&acols, &cfg);
    let mut mask = vec![false; da.num_rows()];
    for_each_budgeted_chunk(b, &budget, |chunk, _| {
        let (ccols, _, cset) = row_set(chunk);
        for (i, m) in mask.iter_mut().enumerate() {
            if *m {
                continue;
            }
            if cset.get(&ah[i]).is_some_and(|cands| {
                cands.iter().any(|&j| rows_eq(&acols, i, &ccols, j as usize))
            }) {
                *m = true;
            }
        }
        Ok(())
    })?;
    Ok(mask)
}

/// Rows of `a` (distinct) that also appear in `b` (INTERSECT).
/// Null cells match null cells, consistent with `drop_duplicates`.
pub fn intersect(a: &Table, b: &Table) -> Result<Table> {
    check_union_compatible(a, b)?;
    let da = drop_duplicates(a, None)?;
    let mask = membership_mask(&da, b)?;
    let idx: Vec<usize> = (0..da.num_rows()).filter(|&i| mask[i]).collect();
    Ok(da.take(&idx))
}

/// Rows of `a` (distinct) that do NOT appear in `b` (EXCEPT).
/// Null cells match null cells, consistent with `drop_duplicates`.
pub fn difference(a: &Table, b: &Table) -> Result<Table> {
    check_union_compatible(a, b)?;
    let da = drop_duplicates(a, None)?;
    let mask = membership_mask(&da, b)?;
    let idx: Vec<usize> = (0..da.num_rows()).filter(|&i| !mask[i]).collect();
    Ok(da.take(&idx))
}

/// Cartesian product: every pair of rows; right columns renamed on
/// collision as in join.
pub fn cartesian(a: &Table, b: &Table) -> Result<Table> {
    let (n, m) = (a.num_rows(), b.num_rows());
    let mut aidx = Vec::with_capacity(n * m);
    let mut bidx = Vec::with_capacity(n * m);
    for i in 0..n {
        for j in 0..m {
            aidx.push(i);
            bidx.push(j);
        }
    }
    let left = a.take(&aidx);
    let right = b.take(&bidx);
    let mut out = left;
    for (f, c) in right.schema().fields().iter().zip(right.columns()) {
        let name = if out.schema().contains(&f.name) {
            format!("{}_r", f.name)
        } else {
            f.name.clone()
        };
        out = out.with_column(&name, c.clone())?;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::table::Scalar;

    fn ta() -> Table {
        Table::from_columns(vec![
            ("k", Array::from_i64(vec![1, 2, 2, 3])),
            ("v", Array::from_strs(&["a", "b", "b", "c"])),
        ])
        .unwrap()
    }

    fn tb() -> Table {
        Table::from_columns(vec![
            ("k", Array::from_i64(vec![2, 4])),
            ("v", Array::from_strs(&["b", "d"])),
        ])
        .unwrap()
    }

    #[test]
    fn union_dedups() {
        let u = union(&ta(), &tb()).unwrap();
        assert_eq!(u.num_rows(), 4); // (1,a),(2,b),(3,c),(4,d)
        let ua = union_all(&ta(), &tb()).unwrap();
        assert_eq!(ua.num_rows(), 6);
    }

    #[test]
    fn intersect_difference() {
        let i = intersect(&ta(), &tb()).unwrap();
        assert_eq!(i.num_rows(), 1);
        assert_eq!(i.cell(0, 0), Scalar::Int64(2));
        let d = difference(&ta(), &tb()).unwrap();
        assert_eq!(d.num_rows(), 2); // (1,a),(3,c)
        let d2 = difference(&tb(), &ta()).unwrap();
        assert_eq!(d2.num_rows(), 1); // (4,d)
    }

    #[test]
    fn incompatible_schemas_rejected() {
        let c = ta().select_columns(&["k"]).unwrap();
        assert!(union(&ta(), &c).is_err());
        assert!(intersect(&ta(), &c).is_err());
        assert!(difference(&ta(), &c).is_err());
    }

    #[test]
    fn mismatched_column_names_rejected() {
        // Same types positionally, different name: must error, not
        // silently zip "w" under "v".
        let renamed = tb().rename("v", "w").unwrap();
        assert!(union_all(&ta(), &renamed).is_err());
        assert!(union(&ta(), &renamed).is_err());
        assert!(intersect(&ta(), &renamed).is_err());
        assert!(difference(&ta(), &renamed).is_err());
    }

    #[test]
    fn mismatched_column_types_rejected() {
        // Same names, different type for "k".
        let retyped = Table::from_columns(vec![
            ("k", Array::from_strs(&["2", "4"])),
            ("v", Array::from_strs(&["b", "d"])),
        ])
        .unwrap();
        assert!(union_all(&ta(), &retyped).is_err());
        assert!(union(&ta(), &retyped).is_err());
        assert!(intersect(&ta(), &retyped).is_err());
        assert!(difference(&ta(), &retyped).is_err());
    }

    #[test]
    fn null_bearing_key_columns() {
        // Null == null in set-op semantics (same as drop_duplicates).
        let a = Table::from_columns(vec![
            ("k", Array::from_opt_i64(vec![Some(1), None, None])),
            ("v", Array::from_strs(&["a", "n", "n"])),
        ])
        .unwrap();
        let b = Table::from_columns(vec![
            ("k", Array::from_opt_i64(vec![None, Some(2)])),
            ("v", Array::from_strs(&["n", "b"])),
        ])
        .unwrap();
        let i = intersect(&a, &b).unwrap();
        assert_eq!(i.num_rows(), 1, "the (null, n) row matches across tables");
        assert_eq!(i.cell(0, 0), Scalar::Null);
        let d = difference(&a, &b).unwrap();
        assert_eq!(d.num_rows(), 1); // only (1, a) survives
        assert_eq!(d.cell(0, 0), Scalar::Int64(1));
        let u = union(&a, &b).unwrap();
        assert_eq!(u.num_rows(), 3); // (1,a), (null,n), (2,b)
        assert_eq!(u.column_by_name("k").unwrap().null_count(), 1);
    }

    #[test]
    fn empty_both_sides() {
        let e = ta().slice(0, 0);
        assert_eq!(union(&e, &e).unwrap().num_rows(), 0);
        assert_eq!(union_all(&e, &e).unwrap().num_rows(), 0);
        assert_eq!(intersect(&e, &e).unwrap().num_rows(), 0);
        assert_eq!(difference(&e, &e).unwrap().num_rows(), 0);
        // schema survives the empty set op
        assert_eq!(union(&e, &e).unwrap().schema().names(), vec!["k", "v"]);
    }

    #[test]
    fn cartesian_product() {
        let c = cartesian(&ta().head(2), &tb()).unwrap();
        assert_eq!(c.num_rows(), 4);
        assert_eq!(c.num_columns(), 4);
        assert_eq!(c.schema().names(), vec!["k", "v", "k_r", "v_r"]);
    }

    #[test]
    fn empty_inputs() {
        let e = ta().slice(0, 0);
        assert_eq!(union(&ta(), &e).unwrap().num_rows(), 3);
        assert_eq!(intersect(&ta(), &e).unwrap().num_rows(), 0);
        assert_eq!(difference(&e, &ta()).unwrap().num_rows(), 0);
        assert_eq!(cartesian(&ta(), &e).unwrap().num_rows(), 0);
    }
}
