//! GroupBy + Aggregate (Table 2, "GroupBy", "Aggregate").
//!
//! Hash-grouping over key columns followed by single-pass columnar
//! accumulation. The distributed group-by (shuffle by key hash + local
//! group-by) reuses this kernel.

use super::select::{filter_cmp, Cmp};
use crate::table::rowhash::{hash_columns, rows_eq};
use crate::table::{Array, ArrayBuilder, DataType, Field, Scalar, Schema, Table};
use anyhow::{bail, Result};
use std::collections::{HashMap, HashSet};

/// Aggregation function.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Agg {
    Sum,
    Mean,
    Min,
    Max,
    Count,
    /// Population standard deviation.
    Std,
    /// Population variance.
    Var,
    First,
    Last,
}

impl Agg {
    pub fn name(&self) -> &'static str {
        match self {
            Agg::Sum => "sum",
            Agg::Mean => "mean",
            Agg::Min => "min",
            Agg::Max => "max",
            Agg::Count => "count",
            Agg::Std => "std",
            Agg::Var => "var",
            Agg::First => "first",
            Agg::Last => "last",
        }
    }

    /// Output type given the input column type.
    fn out_type(&self, input: DataType) -> Result<DataType> {
        Ok(match self {
            Agg::Count => DataType::Int64,
            Agg::Mean | Agg::Std | Agg::Var => {
                if !input.is_numeric() {
                    bail!("{} requires a numeric column, got {input}", self.name());
                }
                DataType::Float64
            }
            Agg::Sum => {
                if !input.is_numeric() {
                    bail!("sum requires a numeric column, got {input}");
                }
                input
            }
            Agg::Min | Agg::Max | Agg::First | Agg::Last => input,
        })
    }
}

/// One aggregation request: `(input column, function, output name)`.
#[derive(Debug, Clone)]
pub struct AggSpec {
    pub column: String,
    pub agg: Agg,
    pub out_name: String,
}

impl AggSpec {
    pub fn new(column: impl Into<String>, agg: Agg) -> AggSpec {
        let column = column.into();
        let out_name = format!("{column}_{}", agg.name());
        AggSpec { column, agg, out_name }
    }

    pub fn named(column: impl Into<String>, agg: Agg, out_name: impl Into<String>) -> AggSpec {
        AggSpec { column: column.into(), agg, out_name: out_name.into() }
    }
}

/// Group assignment: for each row, its group id; plus one representative
/// row per group (first occurrence, in first-seen order).
pub fn group_ids(table: &Table, keys: &[&str]) -> Result<(Vec<usize>, Vec<usize>)> {
    let key_cols: Vec<&Array> = keys
        .iter()
        .map(|k| table.column_by_name(k))
        .collect::<Result<_>>()?;
    if key_cols.is_empty() {
        bail!("groupby: no key columns");
    }
    let hashes = hash_columns(&key_cols);
    let n = table.num_rows();
    let mut ids = Vec::with_capacity(n);
    let mut reps: Vec<usize> = Vec::new();
    // Compact chaining (EXPERIMENTS.md §Perf): hash -> first group id
    // (1-based) in `seen`, collision chain in `next_group` — no per-key
    // Vec allocation.
    let mut seen: HashMap<u64, u32> = HashMap::with_capacity(n);
    let mut next_group: Vec<u32> = Vec::new(); // per group, 0 = end
    for i in 0..n {
        let slot = seen.entry(hashes[i]).or_insert(0);
        let mut cur = *slot;
        let mut gid = None;
        while cur != 0 {
            let g = (cur - 1) as usize;
            if rows_eq(&key_cols, i, &key_cols, reps[g]) {
                gid = Some(g);
                break;
            }
            cur = next_group[g];
        }
        let g = match gid {
            Some(g) => g,
            None => {
                let g = reps.len();
                reps.push(i);
                // prepend to the chain for this hash
                next_group.push(*slot);
                *slot = (g + 1) as u32;
                g
            }
        };
        ids.push(g);
    }
    Ok((ids, reps))
}

/// Columnar accumulator for one aggregation over all groups.
enum Acc {
    F64 { sum: Vec<f64>, count: Vec<u64> },
    MinMaxF64(Vec<Option<f64>>),
    MinMaxI64(Vec<Option<i64>>),
    /// Same arithmetic as MinMaxI64 but finishes to a Timestamp column
    /// (min/max of a temporal column is still a temporal instant).
    MinMaxTs(Vec<Option<i64>>),
    MinMaxStr(Vec<Option<String>>),
    Count(Vec<i64>),
    /// mean/std/var via Welford-free two-accumulator (sum, sumsq, count)
    Moments { sum: Vec<f64>, sumsq: Vec<f64>, count: Vec<u64> },
    FirstLast(Vec<Option<usize>>, bool /* last? */),
    SumI64(Vec<i64>),
}

fn finish_acc(acc: Acc, agg: Agg, src: &Array) -> Array {
    match acc {
        Acc::F64 { sum, .. } => Array::from_f64(sum),
        Acc::SumI64(v) => Array::from_i64(v),
        Acc::Count(v) => Array::from_i64(v),
        Acc::MinMaxF64(v) => Array::from_opt_f64(v),
        Acc::MinMaxI64(v) => Array::from_opt_i64(v),
        Acc::MinMaxTs(v) => Array::from_opt_ts(v),
        Acc::MinMaxStr(v) => {
            Array::from_opt_strs(v.iter().map(|o| o.as_deref()).collect())
        }
        Acc::Moments { sum, sumsq, count } => {
            let out: Vec<Option<f64>> = sum
                .iter()
                .zip(sumsq.iter())
                .zip(count.iter())
                .map(|((&s, &ss), &c)| {
                    if c == 0 {
                        None
                    } else {
                        let mean = s / c as f64;
                        match agg {
                            Agg::Mean => Some(mean),
                            Agg::Var => Some((ss / c as f64 - mean * mean).max(0.0)),
                            Agg::Std => Some((ss / c as f64 - mean * mean).max(0.0).sqrt()),
                            _ => unreachable!(),
                        }
                    }
                })
                .collect();
            Array::from_opt_f64(out)
        }
        Acc::FirstLast(rows, _) => {
            let mut b = ArrayBuilder::with_capacity(src.data_type(), rows.len());
            for r in rows {
                match r {
                    Some(i) => b.push_from(src, i),
                    None => b.push_null(),
                }
            }
            b.finish()
        }
    }
}

/// Group by `keys` and compute `aggs`. Output: key columns (group
/// representatives, first-seen order) then one column per agg.
pub fn groupby_aggregate(table: &Table, keys: &[&str], aggs: &[AggSpec]) -> Result<Table> {
    let (ids, reps) = group_ids(table, keys)?;
    let ngroups = reps.len();

    let mut out_fields: Vec<Field> = Vec::new();
    let mut out_cols: Vec<Array> = Vec::new();

    // Key columns: gather group representatives.
    for k in keys {
        let col = table.column_by_name(k)?;
        out_fields.push(Field::new(*k, col.data_type()));
        out_cols.push(col.take(&reps));
    }

    for spec in aggs {
        let src = table.column_by_name(&spec.column)?;
        let out_ty = spec.agg.out_type(src.data_type())?;
        let mut acc = match (spec.agg, src.data_type()) {
            (Agg::Count, _) => Acc::Count(vec![0; ngroups]),
            (Agg::Sum, DataType::Int64) => Acc::SumI64(vec![0; ngroups]),
            (Agg::Sum, _) => Acc::F64 { sum: vec![0.0; ngroups], count: vec![0; ngroups] },
            (Agg::Mean | Agg::Std | Agg::Var, _) => Acc::Moments {
                sum: vec![0.0; ngroups],
                sumsq: vec![0.0; ngroups],
                count: vec![0; ngroups],
            },
            (Agg::Min | Agg::Max, DataType::Int64) => Acc::MinMaxI64(vec![None; ngroups]),
            (Agg::Min | Agg::Max, DataType::Timestamp) => Acc::MinMaxTs(vec![None; ngroups]),
            (Agg::Min | Agg::Max, DataType::Float64) => Acc::MinMaxF64(vec![None; ngroups]),
            (Agg::Min | Agg::Max, DataType::Utf8) => Acc::MinMaxStr(vec![None; ngroups]),
            (Agg::Min | Agg::Max, DataType::Bool) => {
                bail!("min/max on bool not supported")
            }
            (Agg::First, _) => Acc::FirstLast(vec![None; ngroups], false),
            (Agg::Last, _) => Acc::FirstLast(vec![None; ngroups], true),
        };

        let want_max = spec.agg == Agg::Max;
        for (i, &g) in ids.iter().enumerate() {
            match &mut acc {
                Acc::Count(v) => {
                    if src.is_valid(i) {
                        v[g] += 1;
                    }
                }
                Acc::SumI64(v) => {
                    if let Array::Int64(vals, _) = src {
                        if src.is_valid(i) {
                            v[g] += vals[i];
                        }
                    }
                }
                Acc::F64 { sum, count } => {
                    if let Some(x) = src.f64_at(i) {
                        sum[g] += x;
                        count[g] += 1;
                    }
                }
                Acc::Moments { sum, sumsq, count } => {
                    if let Some(x) = src.f64_at(i) {
                        sum[g] += x;
                        sumsq[g] += x * x;
                        count[g] += 1;
                    }
                }
                Acc::MinMaxI64(v) => {
                    if let (Array::Int64(vals, _), true) = (src, src.is_valid(i)) {
                        let x = vals[i];
                        v[g] = Some(match v[g] {
                            None => x,
                            Some(c) if want_max => c.max(x),
                            Some(c) => c.min(x),
                        });
                    }
                }
                Acc::MinMaxTs(v) => {
                    if let (Array::Timestamp(vals, _), true) = (src, src.is_valid(i)) {
                        let x = vals[i];
                        v[g] = Some(match v[g] {
                            None => x,
                            Some(c) if want_max => c.max(x),
                            Some(c) => c.min(x),
                        });
                    }
                }
                Acc::MinMaxF64(v) => {
                    if let Some(x) = src.f64_at(i) {
                        v[g] = Some(match v[g] {
                            None => x,
                            Some(c) if want_max => c.max(x),
                            Some(c) => c.min(x),
                        });
                    }
                }
                Acc::MinMaxStr(v) => {
                    // `str_at` covers both the plain and the
                    // dictionary-encoded Utf8 layouts.
                    if let (Some(x), true) = (src.str_at(i), src.is_valid(i)) {
                        match &v[g] {
                            None => v[g] = Some(x.to_string()),
                            Some(c) => {
                                if (want_max && x > c.as_str()) || (!want_max && x < c.as_str()) {
                                    v[g] = Some(x.to_string());
                                }
                            }
                        }
                    }
                }
                Acc::FirstLast(v, last) => {
                    if src.is_valid(i) && (*last || v[g].is_none()) {
                        v[g] = Some(i);
                    }
                }
            }
        }
        let arr = finish_acc(acc, spec.agg, src);
        debug_assert_eq!(arr.data_type(), out_ty);
        out_fields.push(Field::new(spec.out_name.clone(), out_ty));
        out_cols.push(arr);
    }

    Table::new(Schema::new(out_fields), out_cols)
}

/// How one requested aggregation is reassembled from the re-reduced
/// partial columns.
#[derive(Debug, Clone)]
enum FinishPlan {
    /// The final column is the re-reduced partial, renamed to the
    /// caller's output name.
    Carry { part: String },
    /// Mean = global sum / global count, null when the count is zero
    /// (matching the local kernel's all-null-group behaviour).
    Mean { sum: String, cnt: String },
    /// Retractable sum: the partial sum is NaN-sanitised, the NaN
    /// occurrences counted separately; the final sum is NaN whenever
    /// any survived — identical to folding the raw values.
    SumNan { sum: String, nan: String },
    /// Retractable mean: sum/count pair plus the NaN occurrence count.
    MeanNan { sum: String, cnt: String, nan: String },
}

/// Synthetic input columns a retractable plan adds to every batch
/// before partial aggregation (see [`PartialAggPlan::new_retractable`]).
#[derive(Debug, Clone)]
struct RetractCols {
    /// All-ones input column: its per-group sum counts every row of the
    /// group (nulls included), so retraction knows when a key's rows
    /// have all expired.
    ones_input: String,
    /// Partial column holding that per-group row count.
    rows_part: String,
    /// `(source column, indicator input column)` for every retractable
    /// sum source: the indicator counts NaN payloads while the source
    /// itself is zeroed where NaN, keeping partial sums finite.
    nan_inputs: Vec<(String, String)>,
}

/// A decomposition of aggregation requests into associative partials —
/// the "combine" side of the map/combine/shuffle/reduce pattern
/// (arXiv 2010.06312), shared by the distributed map-side-combine
/// group-by (`ops::dist::dist_groupby_partial`) and the streaming
/// pipeline's stateful `keyed_aggregate` stage.
///
/// The lifecycle is `partial → (merge…) → finish`:
///
/// 1. [`partial_specs`](Self::partial_specs) aggregates raw rows into
///    one partial row per group (`Sum`/`Count`/`Min`/`Max` columns;
///    `Mean` is carried as a sum + count pair, interned so overlapping
///    requests share one column);
/// 2. any number of partial tables (from other ranks, or from earlier
///    stream batches) merge by concatenation + re-grouping with
///    [`reduce_specs`](Self::reduce_specs) — each reduce writes back to
///    the same column name, so merging is closed and can repeat
///    (`fold` is the streaming form);
/// 3. [`finish`](Self::finish) reassembles the caller's requested
///    layout, deriving `Mean` from the sum/count pair.
///
/// `Std`/`Var`/`First`/`Last` do not decompose over this partial set
/// and are rejected by [`new`](Self::new).
///
/// A plan built with [`new_retractable`](Self::new_retractable)
/// additionally supports [`unfold`](Self::unfold) — the exact inverse
/// of `fold` — which sliding windows use to subtract evicted batches
/// from a running state instead of recomputing the window.
#[derive(Debug, Clone)]
pub struct PartialAggPlan {
    requested: Vec<AggSpec>,
    partial: Vec<AggSpec>,
    reduce: Vec<AggSpec>,
    plans: Vec<FinishPlan>,
    /// `Some` for retractable plans: the synthetic-column bookkeeping
    /// that makes subtraction exact (row presence + NaN counts).
    retract: Option<RetractCols>,
}

/// Shared scaffolding of both [`PartialAggPlan`] constructors: interns
/// partial columns (so overlapping requests like `Sum(v)` + `Mean(v)` +
/// `Count(v)` compute and ship each distinct `(column, partial)` exactly
/// once) and derives the reduce specs that write every partial back
/// onto its own name.
#[derive(Default)]
struct PlanBuilder {
    partial: Vec<AggSpec>,
    refine: Vec<Agg>, // parallel to `partial`
    index: HashMap<(String, &'static str), String>,
}

impl PlanBuilder {
    fn intern(&mut self, column: &str, kind: Agg, reduce: Agg) -> String {
        let slot = (column.to_string(), kind.name());
        if let Some(name) = self.index.get(&slot) {
            return name.clone();
        }
        let name = format!("__p{}_{}", self.partial.len(), kind.name());
        self.index.insert(slot, name.clone());
        self.partial.push(AggSpec::named(column, kind, name.clone()));
        self.refine.push(reduce);
        name
    }

    fn reduce_specs(&self) -> Vec<AggSpec> {
        self.partial
            .iter()
            .zip(&self.refine)
            .map(|(p, agg)| AggSpec::named(p.out_name.clone(), *agg, p.out_name.clone()))
            .collect()
    }
}

impl PartialAggPlan {
    /// Decompose `aggs`; errors on non-decomposable aggregations.
    pub fn new(aggs: &[AggSpec]) -> Result<PartialAggPlan> {
        let mut b = PlanBuilder::default();
        let mut plans: Vec<FinishPlan> = Vec::with_capacity(aggs.len());
        for spec in aggs {
            let plan = match spec.agg {
                Agg::Sum => {
                    FinishPlan::Carry { part: b.intern(&spec.column, Agg::Sum, Agg::Sum) }
                }
                Agg::Count => {
                    FinishPlan::Carry { part: b.intern(&spec.column, Agg::Count, Agg::Sum) }
                }
                Agg::Min => {
                    FinishPlan::Carry { part: b.intern(&spec.column, Agg::Min, Agg::Min) }
                }
                Agg::Max => {
                    FinishPlan::Carry { part: b.intern(&spec.column, Agg::Max, Agg::Max) }
                }
                Agg::Mean => FinishPlan::Mean {
                    sum: b.intern(&spec.column, Agg::Sum, Agg::Sum),
                    cnt: b.intern(&spec.column, Agg::Count, Agg::Sum),
                },
                other => bail!(
                    "{} does not decompose into partial aggregates; \
                     use the full-shuffle group-by",
                    other.name()
                ),
            };
            plans.push(plan);
        }
        Ok(PartialAggPlan {
            requested: aggs.to_vec(),
            reduce: b.reduce_specs(),
            partial: b.partial,
            plans,
            retract: None,
        })
    }

    /// Decompose `aggs` into partials that also subtract exactly, so a
    /// sliding window can evict old batches from a running state via
    /// [`unfold`](Self::unfold) instead of recomputing the window.
    ///
    /// Only `Sum`/`Count`/`Mean` qualify. Two synthetic partials make
    /// the subtraction an exact inverse of [`fold`](Self::fold):
    ///
    /// * a per-group **row count** (`__ones` summed) tracks key
    ///   liveness — a key whose rows have all expired is dropped, which
    ///   plain sum/count columns cannot express (they just reach zero);
    /// * per retractable-sum source, a **NaN count** while the source
    ///   values are zeroed where NaN — `x + NaN` is irreversible, so
    ///   sums stay finite in the state and [`finish`](Self::finish)
    ///   re-poisons totals whose window still contains a NaN.
    ///
    /// Float sums retract bit-exactly when payload magnitudes are
    /// integral (the harness convention); arbitrary reals subtract to
    /// within rounding, like any running-sum implementation.
    pub fn new_retractable(aggs: &[AggSpec]) -> Result<PartialAggPlan> {
        let mut b = PlanBuilder::default();
        let mut plans: Vec<FinishPlan> = Vec::with_capacity(aggs.len());
        let mut nan_src: Vec<String> = Vec::new();
        for spec in aggs {
            let nan = if matches!(spec.agg, Agg::Sum | Agg::Mean) {
                if !nan_src.contains(&spec.column) {
                    nan_src.push(spec.column.clone());
                }
                Some(b.intern(&format!("__nan_{}", spec.column), Agg::Sum, Agg::Sum))
            } else {
                None
            };
            let plan = match spec.agg {
                Agg::Sum => {
                    let sum = b.intern(&spec.column, Agg::Sum, Agg::Sum);
                    FinishPlan::SumNan { sum, nan: nan.unwrap() }
                }
                Agg::Count => {
                    FinishPlan::Carry { part: b.intern(&spec.column, Agg::Count, Agg::Sum) }
                }
                Agg::Mean => {
                    let sum = b.intern(&spec.column, Agg::Sum, Agg::Sum);
                    let cnt = b.intern(&spec.column, Agg::Count, Agg::Sum);
                    FinishPlan::MeanNan { sum, cnt, nan: nan.unwrap() }
                }
                other => bail!(
                    "{} does not retract exactly on an unbounded stream; sliding \
                     windows rebuild min/max per window from the bounded segment \
                     ring (Eviction::Auto or Eviction::Rebuild), and \
                     std/var/first/last do not decompose at all",
                    other.name()
                ),
            };
            plans.push(plan);
        }
        let ones_input = "__ones".to_string();
        let rows_part = b.intern(&ones_input, Agg::Sum, Agg::Sum);
        let nan_inputs =
            nan_src.into_iter().map(|c| (c.clone(), format!("__nan_{c}"))).collect();
        Ok(PartialAggPlan {
            requested: aggs.to_vec(),
            reduce: b.reduce_specs(),
            partial: b.partial,
            plans,
            retract: Some(RetractCols { ones_input, rows_part, nan_inputs }),
        })
    }

    /// Whether this plan was built with
    /// [`new_retractable`](Self::new_retractable) and therefore has an
    /// [`unfold`](Self::unfold) path.
    pub fn is_retractable(&self) -> bool {
        self.retract.is_some()
    }

    /// Whether every aggregation in `aggs` subtracts exactly
    /// (`Sum`/`Count`/`Mean`) — the gate for choosing subtract-on-evict
    /// over per-window rebuild.
    pub fn aggs_retract_exactly(aggs: &[AggSpec]) -> bool {
        aggs.iter().all(|s| matches!(s.agg, Agg::Sum | Agg::Count | Agg::Mean))
    }

    /// Specs that turn raw rows into one partial row per group.
    pub fn partial_specs(&self) -> &[AggSpec] {
        &self.partial
    }

    /// Specs that merge concatenated partial tables (each writes back
    /// to its own column name, so reducing is closed under repetition).
    pub fn reduce_specs(&self) -> &[AggSpec] {
        &self.reduce
    }

    /// Synthesise the extra input columns a retractable plan aggregates:
    /// the `__ones` row counter, and per retractable-sum source a NaN
    /// indicator while NaN payloads are zeroed out of the source copy.
    /// Only the columns the partial set actually reads (keys + agg
    /// sources) are copied — this runs per batch on the streaming hot
    /// path.
    fn prepare(&self, batch: &Table, keys: &[&str]) -> Result<Table> {
        let Some(r) = &self.retract else {
            return Ok(batch.clone());
        };
        // Fail fast on name collisions: Schema allows duplicate field
        // names and lookups return the first match, so a user column
        // shadowing a synthetic one would silently corrupt liveness /
        // NaN accounting instead of erroring.
        for reserved in std::iter::once(&r.ones_input).chain(r.nan_inputs.iter().map(|(_, i)| i))
        {
            if batch.schema().contains(reserved) {
                bail!(
                    "retractable aggregation reserves the column name {reserved:?} \
                     for its internal bookkeeping; rename that input column"
                );
            }
        }
        let mut names: Vec<&str> = keys.to_vec();
        for p in &self.partial {
            let c = p.column.as_str();
            let synthetic = c == r.ones_input || r.nan_inputs.iter().any(|(_, i)| i == c);
            if !synthetic && !names.contains(&c) {
                names.push(c);
            }
        }
        let batch = batch.select_columns(&names)?;
        let n = batch.num_rows();
        let mut fields: Vec<Field> = batch.schema().fields().to_vec();
        let mut cols: Vec<Array> = batch.columns().to_vec();
        for (src, ind) in &r.nan_inputs {
            let idx = batch.schema().index_of(src)?;
            let mut flags = vec![0i64; n];
            if let Array::Float64(vals, valid) = &cols[idx] {
                let valid = valid.clone();
                let mut sane = vals.clone();
                for (i, flag) in flags.iter_mut().enumerate() {
                    let ok = match valid.as_ref() {
                        None => true,
                        Some(b) => b.get(i),
                    };
                    if ok && sane[i].is_nan() {
                        *flag = 1;
                        sane[i] = 0.0;
                    }
                }
                cols[idx] = Array::Float64(sane, valid);
            }
            fields.push(Field::new(ind.clone(), DataType::Int64));
            cols.push(Array::from_i64(flags));
        }
        fields.push(Field::new(r.ones_input.clone(), DataType::Int64));
        cols.push(Array::from_i64(vec![1; n]));
        Table::new(Schema::new(fields), cols)
    }

    /// Aggregate one raw batch into a standalone partial table (one row
    /// per group present in the batch).
    pub fn partial(&self, batch: &Table, keys: &[&str]) -> Result<Table> {
        match &self.retract {
            None => groupby_aggregate(batch, keys, &self.partial),
            Some(_) => groupby_aggregate(&self.prepare(batch, keys)?, keys, &self.partial),
        }
    }

    /// Merge one partial table into an optional running partial state
    /// by concatenation + re-reduce (closed under repetition).
    pub fn merge(&self, state: Option<Table>, partial: &Table, keys: &[&str]) -> Result<Table> {
        match state {
            None => Ok(partial.clone()),
            Some(prev) => {
                let cat = Table::concat_tables(&[&prev, partial])?;
                groupby_aggregate(&cat, keys, &self.reduce)
            }
        }
    }

    /// Fold one raw batch into an optional running partial state (the
    /// streaming form): [`partial`](Self::partial) then
    /// [`merge`](Self::merge).
    pub fn fold(&self, state: Option<Table>, batch: &Table, keys: &[&str]) -> Result<Table> {
        let p = self.partial(batch, keys)?;
        match state {
            None => Ok(p),
            Some(prev) => self.merge(Some(prev), &p, keys),
        }
    }

    /// Subtract previously-folded partials from a running state — the
    /// exact inverse of [`fold`](Self::fold) for plans built with
    /// [`new_retractable`](Self::new_retractable). Keys whose row
    /// presence drops to zero leave the state entirely, so repeated
    /// fold/unfold cycles stay bounded by the live window, not the
    /// stream.
    pub fn unfold(&self, state: &Table, evicted: &Table, keys: &[&str]) -> Result<Table> {
        let Some(r) = &self.retract else {
            bail!(
                "unfold needs a retractable plan; build it with \
                 PartialAggPlan::new_retractable"
            );
        };
        // Negate every partial column of the evicted table (keys pass
        // through), then retraction is just another merge.
        let part_names: HashSet<&str> =
            self.partial.iter().map(|p| p.out_name.as_str()).collect();
        let mut fields = Vec::with_capacity(evicted.num_columns());
        let mut cols = Vec::with_capacity(evicted.num_columns());
        for (f, c) in evicted.schema().fields().iter().zip(evicted.columns()) {
            let col = if part_names.contains(f.name.as_str()) { negate(c)? } else { c.clone() };
            fields.push(f.clone());
            cols.push(col);
        }
        let neg = Table::new(Schema::new(fields), cols)?;
        let red = self.merge(Some(state.clone()), &neg, keys)?;
        filter_cmp(&red, &r.rows_part, Cmp::Gt, &Scalar::Int64(0))
    }

    /// Reassemble the fully-reduced partial table `combined` into the
    /// caller's requested layout: key columns, then one column per
    /// requested aggregation, named exactly as the one-shot local
    /// kernel would name it.
    pub fn finish(&self, keys: &[&str], combined: &Table) -> Result<Table> {
        let mut fields: Vec<Field> = Vec::new();
        let mut cols: Vec<Array> = Vec::new();
        for k in keys {
            let a = combined.column_by_name(k)?;
            fields.push(Field::new(*k, a.data_type()));
            cols.push(a.clone());
        }
        for (spec, plan) in self.requested.iter().zip(&self.plans) {
            match plan {
                FinishPlan::Carry { part } => {
                    let a = combined.column_by_name(part)?;
                    fields.push(Field::new(spec.out_name.clone(), a.data_type()));
                    cols.push(a.clone());
                }
                FinishPlan::Mean { sum, cnt } => {
                    let s = combined.column_by_name(sum)?;
                    let c = combined.column_by_name(cnt)?;
                    let vals: Vec<Option<f64>> = (0..combined.num_rows())
                        .map(|i| match (s.f64_at(i), c.f64_at(i)) {
                            (Some(sv), Some(cv)) if cv > 0.0 => Some(sv / cv),
                            _ => None,
                        })
                        .collect();
                    fields.push(Field::new(spec.out_name.clone(), DataType::Float64));
                    cols.push(Array::from_opt_f64(vals));
                }
                FinishPlan::SumNan { sum, nan } => {
                    let s = combined.column_by_name(sum)?;
                    let nn = combined.column_by_name(nan)?;
                    match s {
                        Array::Float64(v, _) => {
                            let vals: Vec<f64> = (0..combined.num_rows())
                                .map(|i| {
                                    if nn.f64_at(i).unwrap_or(0.0) > 0.0 {
                                        f64::NAN
                                    } else {
                                        v[i]
                                    }
                                })
                                .collect();
                            fields.push(Field::new(spec.out_name.clone(), DataType::Float64));
                            cols.push(Array::from_f64(vals));
                        }
                        // Integer sums never see NaN: carry directly.
                        _ => {
                            fields.push(Field::new(spec.out_name.clone(), s.data_type()));
                            cols.push(s.clone());
                        }
                    }
                }
                FinishPlan::MeanNan { sum, cnt, nan } => {
                    let s = combined.column_by_name(sum)?;
                    let c = combined.column_by_name(cnt)?;
                    let nn = combined.column_by_name(nan)?;
                    let vals: Vec<Option<f64>> = (0..combined.num_rows())
                        .map(|i| match (s.f64_at(i), c.f64_at(i)) {
                            (_, Some(cv)) if cv > 0.0 && nn.f64_at(i).unwrap_or(0.0) > 0.0 => {
                                Some(f64::NAN)
                            }
                            (Some(sv), Some(cv)) if cv > 0.0 => Some(sv / cv),
                            _ => None,
                        })
                        .collect();
                    fields.push(Field::new(spec.out_name.clone(), DataType::Float64));
                    cols.push(Array::from_opt_f64(vals));
                }
            }
        }
        Table::new(Schema::new(fields), cols)
    }
}

/// Negate a numeric partial column so retraction reduces to a merge.
fn negate(a: &Array) -> Result<Array> {
    Ok(match a {
        Array::Int64(v, valid) => Array::Int64(v.iter().map(|x| -x).collect(), valid.clone()),
        Array::Float64(v, valid) => {
            Array::Float64(v.iter().map(|x| -x).collect(), valid.clone())
        }
        other => bail!("cannot retract a {} partial", other.data_type()),
    })
}

/// Whole-table aggregation (no keys): one output row.
pub fn aggregate(table: &Table, aggs: &[AggSpec]) -> Result<Table> {
    // Reuse the grouped path with a constant key, then drop it.
    let tmp = table.with_column("__all", Array::from_i64(vec![0; table.num_rows()]))?;
    if table.num_rows() == 0 {
        // groupby of empty input yields zero groups; synthesise one null row
        let mut fields = Vec::new();
        let mut cols = Vec::new();
        for spec in aggs {
            let src = table.column_by_name(&spec.column)?;
            let ty = spec.agg.out_type(src.data_type())?;
            fields.push(Field::new(spec.out_name.clone(), ty));
            let mut b = ArrayBuilder::with_capacity(ty, 1);
            if spec.agg == Agg::Count {
                b.push_i64(0);
            } else {
                b.push_null();
            }
            cols.push(b.finish());
        }
        return Table::new(Schema::new(fields), cols);
    }
    let g = groupby_aggregate(&tmp, &["__all"], aggs)?;
    g.drop_columns(&["__all"])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::table::Scalar;

    fn t() -> Table {
        Table::from_columns(vec![
            ("g", Array::from_strs(&["a", "b", "a", "b", "a"])),
            ("x", Array::from_opt_i64(vec![Some(1), Some(2), Some(3), None, Some(5)])),
            ("y", Array::from_f64(vec![1.0, 2.0, 3.0, 4.0, 5.0])),
        ])
        .unwrap()
    }

    #[test]
    fn sums_and_counts() {
        let g = groupby_aggregate(
            &t(),
            &["g"],
            &[AggSpec::new("x", Agg::Sum), AggSpec::new("x", Agg::Count)],
        )
        .unwrap();
        assert_eq!(g.num_rows(), 2);
        // first-seen order: a then b
        assert_eq!(g.cell(0, 0), Scalar::Utf8("a".into()));
        assert_eq!(g.cell(0, 1), Scalar::Int64(9)); // 1+3+5
        assert_eq!(g.cell(0, 2), Scalar::Int64(3));
        assert_eq!(g.cell(1, 1), Scalar::Int64(2)); // null skipped
        assert_eq!(g.cell(1, 2), Scalar::Int64(1));
    }

    #[test]
    fn moments() {
        let g = groupby_aggregate(
            &t(),
            &["g"],
            &[
                AggSpec::new("y", Agg::Mean),
                AggSpec::new("y", Agg::Var),
                AggSpec::new("y", Agg::Std),
            ],
        )
        .unwrap();
        // group a: y = 1,3,5 → mean 3, var 8/3
        assert_eq!(g.cell(0, 1), Scalar::Float64(3.0));
        let var = g.cell(0, 2).as_f64().unwrap();
        assert!((var - 8.0 / 3.0).abs() < 1e-12);
        let std = g.cell(0, 3).as_f64().unwrap();
        assert!((std - (8.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn min_max_first_last() {
        let g = groupby_aggregate(
            &t(),
            &["g"],
            &[
                AggSpec::new("x", Agg::Min),
                AggSpec::new("x", Agg::Max),
                AggSpec::new("y", Agg::First),
                AggSpec::new("y", Agg::Last),
            ],
        )
        .unwrap();
        assert_eq!(g.cell(0, 1), Scalar::Int64(1));
        assert_eq!(g.cell(0, 2), Scalar::Int64(5));
        assert_eq!(g.cell(1, 3), Scalar::Float64(2.0));
        assert_eq!(g.cell(1, 4), Scalar::Float64(4.0));
    }

    #[test]
    fn string_min_max() {
        let g = groupby_aggregate(
            &t(),
            &["g"],
            &[AggSpec::new("g", Agg::Min), AggSpec::new("g", Agg::Max)],
        )
        .unwrap();
        assert_eq!(g.cell(0, 1), Scalar::Utf8("a".into()));
    }

    #[test]
    fn dict_keyed_groupby_matches_plain() {
        let plain = t();
        let dict = plain.dict_encode_columns();
        let aggs = [
            AggSpec::new("x", Agg::Sum),
            AggSpec::new("g", Agg::Min),
            AggSpec::new("g", Agg::Max),
            AggSpec::new("y", Agg::Mean),
        ];
        let a = groupby_aggregate(&plain, &["g"], &aggs).unwrap();
        let b = groupby_aggregate(&dict, &["g"], &aggs).unwrap();
        // key columns keep their physical encoding, so compare at the
        // canonical serialization layer, then cell-by-cell.
        use crate::table::ipc;
        assert_eq!(ipc::serialize(&a), ipc::serialize(&b));
        assert!(b.column_by_name("g").unwrap().is_dict(), "dict keys survive take");
    }

    #[test]
    fn timestamp_keys_and_minmax() {
        let tbl = Table::from_columns(vec![
            ("ts", Array::from_ts(vec![1000, 2000, 1000, 2000])),
            ("ev", Array::from_opt_ts(vec![Some(5), Some(7), None, Some(3)])),
            ("v", Array::from_i64(vec![10, 20, 30, 40])),
        ])
        .unwrap();
        // timestamp as the group key
        let g = groupby_aggregate(&tbl, &["ts"], &[AggSpec::new("v", Agg::Sum)]).unwrap();
        assert_eq!(g.num_rows(), 2);
        assert_eq!(g.cell(0, 0), Scalar::Timestamp(1000));
        assert_eq!(g.cell(0, 1), Scalar::Int64(40));
        // min/max/first/last/count on a timestamp column keep the type
        let a = groupby_aggregate(
            &tbl,
            &["ts"],
            &[
                AggSpec::new("ev", Agg::Min),
                AggSpec::new("ev", Agg::Max),
                AggSpec::new("ev", Agg::First),
                AggSpec::new("ev", Agg::Count),
            ],
        )
        .unwrap();
        assert_eq!(a.column(1).data_type(), DataType::Timestamp);
        assert_eq!(a.cell(0, 1), Scalar::Timestamp(5), "min skips the null");
        assert_eq!(a.cell(1, 2), Scalar::Timestamp(7));
        assert_eq!(a.column(3).data_type(), DataType::Timestamp);
        assert_eq!(a.cell(0, 4), Scalar::Int64(1));
        // numeric aggregations reject the temporal type
        for agg in [Agg::Sum, Agg::Mean, Agg::Std, Agg::Var] {
            assert!(groupby_aggregate(&tbl, &["ts"], &[AggSpec::new("ev", agg)]).is_err());
        }
    }

    #[test]
    fn null_keys_form_a_group() {
        let tbl = Table::from_columns(vec![
            ("k", Array::from_opt_i64(vec![None, Some(1), None])),
            ("v", Array::from_i64(vec![10, 20, 30])),
        ])
        .unwrap();
        let g = groupby_aggregate(&tbl, &["k"], &[AggSpec::new("v", Agg::Sum)]).unwrap();
        assert_eq!(g.num_rows(), 2);
        assert_eq!(g.cell(0, 0), Scalar::Null);
        assert_eq!(g.cell(0, 1), Scalar::Int64(40));
    }

    #[test]
    fn whole_table_aggregate() {
        let a = aggregate(&t(), &[AggSpec::new("y", Agg::Sum), AggSpec::new("x", Agg::Count)]).unwrap();
        assert_eq!(a.num_rows(), 1);
        assert_eq!(a.cell(0, 0), Scalar::Float64(15.0));
        assert_eq!(a.cell(0, 1), Scalar::Int64(4));
        // empty input
        let e = aggregate(&t().slice(0, 0), &[AggSpec::new("x", Agg::Count), AggSpec::new("y", Agg::Sum)])
            .unwrap();
        assert_eq!(e.cell(0, 0), Scalar::Int64(0));
        assert_eq!(e.cell(0, 1), Scalar::Null);
    }

    #[test]
    fn type_errors() {
        assert!(groupby_aggregate(&t(), &["g"], &[AggSpec::new("g", Agg::Sum)]).is_err());
        assert!(groupby_aggregate(&t(), &[], &[AggSpec::new("x", Agg::Sum)]).is_err());
    }

    #[test]
    fn partial_plan_interns_overlapping_requests() {
        let plan = PartialAggPlan::new(&[
            AggSpec::new("y", Agg::Sum),
            AggSpec::new("y", Agg::Mean),
            AggSpec::new("y", Agg::Count),
        ])
        .unwrap();
        // mean reuses the sum and count partials: 2 columns, not 4
        assert_eq!(plan.partial_specs().len(), 2);
        assert_eq!(plan.reduce_specs().len(), 2);
    }

    #[test]
    fn partial_plan_rejects_non_decomposable() {
        for agg in [Agg::Std, Agg::Var, Agg::First, Agg::Last] {
            assert!(PartialAggPlan::new(&[AggSpec::new("y", agg)]).is_err(), "{agg:?}");
        }
    }

    fn canon_rows(t: &Table) -> Vec<String> {
        let mut rows: Vec<String> =
            (0..t.num_rows()).map(|i| format!("{:?}", t.row(i))).collect();
        rows.sort();
        rows
    }

    #[test]
    fn retractable_unfold_inverts_fold() {
        let aggs = [
            AggSpec::new("v", Agg::Sum),
            AggSpec::new("v", Agg::Count),
            AggSpec::new("v", Agg::Mean),
        ];
        let plan = PartialAggPlan::new_retractable(&aggs).unwrap();
        assert!(plan.is_retractable());
        let batch = |ks: &[i64], vs: &[f64]| {
            Table::from_columns(vec![
                ("k", Array::from_i64(ks.to_vec())),
                ("v", Array::from_f64(vs.to_vec())),
            ])
            .unwrap()
        };
        let a = batch(&[1, 2, 1], &[10.0, 20.0, 30.0]);
        let b = batch(&[2, 3], &[5.0, 7.0]);
        let c = batch(&[1, 3], &[2.0, 3.0]);
        // fold a, b, c then retract a == fold b, c
        let mut st = None;
        for t in [&a, &b, &c] {
            st = Some(plan.fold(st, t, &["k"]).unwrap());
        }
        let retracted = plan.unfold(&st.unwrap(), &plan.partial(&a, &["k"]).unwrap(), &["k"]).unwrap();
        let want = plan.fold(Some(plan.partial(&b, &["k"]).unwrap()), &c, &["k"]).unwrap();
        assert_eq!(
            canon_rows(&plan.finish(&["k"], &retracted).unwrap()),
            canon_rows(&plan.finish(&["k"], &want).unwrap())
        );
    }

    #[test]
    fn retractable_unfold_drops_dead_keys_and_recovers_from_nan() {
        let plan = PartialAggPlan::new_retractable(&[
            AggSpec::new("v", Agg::Sum),
            AggSpec::new("v", Agg::Mean),
        ])
        .unwrap();
        // key 9 exists only in the evicted batch (with a NaN payload
        // that poisons the running sum until it is retracted); key 1
        // has a null payload in the surviving batch, so it must stay
        // with sum 0.
        let a = Table::from_columns(vec![
            ("k", Array::from_i64(vec![9, 9, 1])),
            ("v", Array::from_opt_f64(vec![Some(f64::NAN), Some(4.0), Some(6.0)])),
        ])
        .unwrap();
        let b = Table::from_columns(vec![
            ("k", Array::from_i64(vec![1, 1])),
            ("v", Array::from_opt_f64(vec![None, None])),
        ])
        .unwrap();
        let st = plan.fold(Some(plan.partial(&a, &["k"]).unwrap()), &b, &["k"]).unwrap();
        // while a is in the window, key 9's sum is NaN
        let full = plan.finish(&["k"], &st).unwrap();
        let nine = (0..full.num_rows())
            .find(|&i| full.cell(i, 0) == Scalar::Int64(9))
            .unwrap();
        assert!(full.cell(nine, 1).as_f64().unwrap().is_nan(), "sum not NaN-poisoned");
        // retract a: key 9 disappears; key 1 survives on null rows only
        let after = plan.unfold(&st, &plan.partial(&a, &["k"]).unwrap(), &["k"]).unwrap();
        let out = plan.finish(&["k"], &after).unwrap();
        assert_eq!(out.num_rows(), 1);
        assert_eq!(out.cell(0, 0), Scalar::Int64(1));
        assert_eq!(out.cell(0, 1), Scalar::Float64(0.0), "sum over all-null rows");
        assert_eq!(out.cell(0, 2), Scalar::Null, "mean over zero valid values");
    }

    #[test]
    fn retractable_plan_rejects_non_subtractable() {
        for agg in [Agg::Min, Agg::Max, Agg::Std, Agg::First] {
            let err = PartialAggPlan::new_retractable(&[AggSpec::new("y", agg)])
                .err()
                .map(|e| format!("{e:#}"))
                .unwrap_or_else(|| panic!("{agg:?} accepted"));
            assert!(err.contains("retract"), "unactionable message: {err}");
        }
        // unfold on a plain plan is an error, not silent corruption
        let plain = PartialAggPlan::new(&[AggSpec::new("y", Agg::Sum)]).unwrap();
        let t = Table::from_columns(vec![
            ("k", Array::from_i64(vec![1])),
            ("y", Array::from_f64(vec![1.0])),
        ])
        .unwrap();
        let p = plain.partial(&t, &["k"]).unwrap();
        assert!(plain.unfold(&p, &p, &["k"]).is_err());
    }

    #[test]
    fn folding_batches_matches_one_shot_groupby() {
        let aggs = [
            AggSpec::new("y", Agg::Sum),
            AggSpec::new("y", Agg::Mean),
            AggSpec::new("x", Agg::Count),
            AggSpec::new("x", Agg::Min),
            AggSpec::new("y", Agg::Max),
        ];
        let full = t();
        let want = groupby_aggregate(&full, &["g"], &aggs).unwrap();
        let plan = PartialAggPlan::new(&aggs).unwrap();
        // fold the table through in three uneven stream batches
        let mut state = None;
        for (start, len) in [(0usize, 2usize), (2, 1), (3, 2)] {
            state = Some(plan.fold(state, &full.slice(start, len), &["g"]).unwrap());
        }
        let got = plan.finish(&["g"], &state.unwrap()).unwrap();
        // same groups in first-seen order, same column names and values
        assert_eq!(got.schema().names(), want.schema().names());
        assert_eq!(canon_rows(&got), canon_rows(&want));
    }
}
