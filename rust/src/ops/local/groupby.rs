//! GroupBy + Aggregate (Table 2, "GroupBy", "Aggregate").
//!
//! Hash-grouping over key columns followed by single-pass columnar
//! accumulation. The distributed group-by (shuffle by key hash + local
//! group-by) reuses this kernel.

use crate::table::rowhash::{hash_columns, rows_eq};
use crate::table::{Array, ArrayBuilder, DataType, Field, Schema, Table};
use anyhow::{bail, Result};
use std::collections::HashMap;

/// Aggregation function.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Agg {
    Sum,
    Mean,
    Min,
    Max,
    Count,
    /// Population standard deviation.
    Std,
    /// Population variance.
    Var,
    First,
    Last,
}

impl Agg {
    pub fn name(&self) -> &'static str {
        match self {
            Agg::Sum => "sum",
            Agg::Mean => "mean",
            Agg::Min => "min",
            Agg::Max => "max",
            Agg::Count => "count",
            Agg::Std => "std",
            Agg::Var => "var",
            Agg::First => "first",
            Agg::Last => "last",
        }
    }

    /// Output type given the input column type.
    fn out_type(&self, input: DataType) -> Result<DataType> {
        Ok(match self {
            Agg::Count => DataType::Int64,
            Agg::Mean | Agg::Std | Agg::Var => {
                if !input.is_numeric() {
                    bail!("{} requires a numeric column, got {input}", self.name());
                }
                DataType::Float64
            }
            Agg::Sum => {
                if !input.is_numeric() {
                    bail!("sum requires a numeric column, got {input}");
                }
                input
            }
            Agg::Min | Agg::Max | Agg::First | Agg::Last => input,
        })
    }
}

/// One aggregation request: `(input column, function, output name)`.
#[derive(Debug, Clone)]
pub struct AggSpec {
    pub column: String,
    pub agg: Agg,
    pub out_name: String,
}

impl AggSpec {
    pub fn new(column: impl Into<String>, agg: Agg) -> AggSpec {
        let column = column.into();
        let out_name = format!("{column}_{}", agg.name());
        AggSpec { column, agg, out_name }
    }

    pub fn named(column: impl Into<String>, agg: Agg, out_name: impl Into<String>) -> AggSpec {
        AggSpec { column: column.into(), agg, out_name: out_name.into() }
    }
}

/// Group assignment: for each row, its group id; plus one representative
/// row per group (first occurrence, in first-seen order).
pub fn group_ids(table: &Table, keys: &[&str]) -> Result<(Vec<usize>, Vec<usize>)> {
    let key_cols: Vec<&Array> = keys
        .iter()
        .map(|k| table.column_by_name(k))
        .collect::<Result<_>>()?;
    if key_cols.is_empty() {
        bail!("groupby: no key columns");
    }
    let hashes = hash_columns(&key_cols);
    let n = table.num_rows();
    let mut ids = Vec::with_capacity(n);
    let mut reps: Vec<usize> = Vec::new();
    // Compact chaining (EXPERIMENTS.md §Perf): hash -> first group id
    // (1-based) in `seen`, collision chain in `next_group` — no per-key
    // Vec allocation.
    let mut seen: HashMap<u64, u32> = HashMap::with_capacity(n);
    let mut next_group: Vec<u32> = Vec::new(); // per group, 0 = end
    for i in 0..n {
        let slot = seen.entry(hashes[i]).or_insert(0);
        let mut cur = *slot;
        let mut gid = None;
        while cur != 0 {
            let g = (cur - 1) as usize;
            if rows_eq(&key_cols, i, &key_cols, reps[g]) {
                gid = Some(g);
                break;
            }
            cur = next_group[g];
        }
        let g = match gid {
            Some(g) => g,
            None => {
                let g = reps.len();
                reps.push(i);
                // prepend to the chain for this hash
                next_group.push(*slot);
                *slot = (g + 1) as u32;
                g
            }
        };
        ids.push(g);
    }
    Ok((ids, reps))
}

/// Columnar accumulator for one aggregation over all groups.
enum Acc {
    F64 { sum: Vec<f64>, count: Vec<u64> },
    MinMaxF64(Vec<Option<f64>>),
    MinMaxI64(Vec<Option<i64>>),
    MinMaxStr(Vec<Option<String>>),
    Count(Vec<i64>),
    /// mean/std/var via Welford-free two-accumulator (sum, sumsq, count)
    Moments { sum: Vec<f64>, sumsq: Vec<f64>, count: Vec<u64> },
    FirstLast(Vec<Option<usize>>, bool /* last? */),
    SumI64(Vec<i64>),
}

fn finish_acc(acc: Acc, agg: Agg, src: &Array) -> Array {
    match acc {
        Acc::F64 { sum, .. } => Array::from_f64(sum),
        Acc::SumI64(v) => Array::from_i64(v),
        Acc::Count(v) => Array::from_i64(v),
        Acc::MinMaxF64(v) => Array::from_opt_f64(v),
        Acc::MinMaxI64(v) => Array::from_opt_i64(v),
        Acc::MinMaxStr(v) => {
            Array::from_opt_strs(v.iter().map(|o| o.as_deref()).collect())
        }
        Acc::Moments { sum, sumsq, count } => {
            let out: Vec<Option<f64>> = sum
                .iter()
                .zip(sumsq.iter())
                .zip(count.iter())
                .map(|((&s, &ss), &c)| {
                    if c == 0 {
                        None
                    } else {
                        let mean = s / c as f64;
                        match agg {
                            Agg::Mean => Some(mean),
                            Agg::Var => Some((ss / c as f64 - mean * mean).max(0.0)),
                            Agg::Std => Some((ss / c as f64 - mean * mean).max(0.0).sqrt()),
                            _ => unreachable!(),
                        }
                    }
                })
                .collect();
            Array::from_opt_f64(out)
        }
        Acc::FirstLast(rows, _) => {
            let mut b = ArrayBuilder::with_capacity(src.data_type(), rows.len());
            for r in rows {
                match r {
                    Some(i) => b.push_from(src, i),
                    None => b.push_null(),
                }
            }
            b.finish()
        }
    }
}

/// Group by `keys` and compute `aggs`. Output: key columns (group
/// representatives, first-seen order) then one column per agg.
pub fn groupby_aggregate(table: &Table, keys: &[&str], aggs: &[AggSpec]) -> Result<Table> {
    let (ids, reps) = group_ids(table, keys)?;
    let ngroups = reps.len();

    let mut out_fields: Vec<Field> = Vec::new();
    let mut out_cols: Vec<Array> = Vec::new();

    // Key columns: gather group representatives.
    for k in keys {
        let col = table.column_by_name(k)?;
        out_fields.push(Field::new(*k, col.data_type()));
        out_cols.push(col.take(&reps));
    }

    for spec in aggs {
        let src = table.column_by_name(&spec.column)?;
        let out_ty = spec.agg.out_type(src.data_type())?;
        let mut acc = match (spec.agg, src.data_type()) {
            (Agg::Count, _) => Acc::Count(vec![0; ngroups]),
            (Agg::Sum, DataType::Int64) => Acc::SumI64(vec![0; ngroups]),
            (Agg::Sum, _) => Acc::F64 { sum: vec![0.0; ngroups], count: vec![0; ngroups] },
            (Agg::Mean | Agg::Std | Agg::Var, _) => Acc::Moments {
                sum: vec![0.0; ngroups],
                sumsq: vec![0.0; ngroups],
                count: vec![0; ngroups],
            },
            (Agg::Min | Agg::Max, DataType::Int64) => Acc::MinMaxI64(vec![None; ngroups]),
            (Agg::Min | Agg::Max, DataType::Float64) => Acc::MinMaxF64(vec![None; ngroups]),
            (Agg::Min | Agg::Max, DataType::Utf8) => Acc::MinMaxStr(vec![None; ngroups]),
            (Agg::Min | Agg::Max, DataType::Bool) => {
                bail!("min/max on bool not supported")
            }
            (Agg::First, _) => Acc::FirstLast(vec![None; ngroups], false),
            (Agg::Last, _) => Acc::FirstLast(vec![None; ngroups], true),
        };

        let want_max = spec.agg == Agg::Max;
        for (i, &g) in ids.iter().enumerate() {
            match &mut acc {
                Acc::Count(v) => {
                    if src.is_valid(i) {
                        v[g] += 1;
                    }
                }
                Acc::SumI64(v) => {
                    if let Array::Int64(vals, _) = src {
                        if src.is_valid(i) {
                            v[g] += vals[i];
                        }
                    }
                }
                Acc::F64 { sum, count } => {
                    if let Some(x) = src.f64_at(i) {
                        sum[g] += x;
                        count[g] += 1;
                    }
                }
                Acc::Moments { sum, sumsq, count } => {
                    if let Some(x) = src.f64_at(i) {
                        sum[g] += x;
                        sumsq[g] += x * x;
                        count[g] += 1;
                    }
                }
                Acc::MinMaxI64(v) => {
                    if let (Array::Int64(vals, _), true) = (src, src.is_valid(i)) {
                        let x = vals[i];
                        v[g] = Some(match v[g] {
                            None => x,
                            Some(c) if want_max => c.max(x),
                            Some(c) => c.min(x),
                        });
                    }
                }
                Acc::MinMaxF64(v) => {
                    if let Some(x) = src.f64_at(i) {
                        v[g] = Some(match v[g] {
                            None => x,
                            Some(c) if want_max => c.max(x),
                            Some(c) => c.min(x),
                        });
                    }
                }
                Acc::MinMaxStr(v) => {
                    if let (Array::Utf8(d, _), true) = (src, src.is_valid(i)) {
                        let x = d.value(i);
                        match &v[g] {
                            None => v[g] = Some(x.to_string()),
                            Some(c) => {
                                if (want_max && x > c.as_str()) || (!want_max && x < c.as_str()) {
                                    v[g] = Some(x.to_string());
                                }
                            }
                        }
                    }
                }
                Acc::FirstLast(v, last) => {
                    if src.is_valid(i) && (*last || v[g].is_none()) {
                        v[g] = Some(i);
                    }
                }
            }
        }
        let arr = finish_acc(acc, spec.agg, src);
        debug_assert_eq!(arr.data_type(), out_ty);
        out_fields.push(Field::new(spec.out_name.clone(), out_ty));
        out_cols.push(arr);
    }

    Table::new(Schema::new(out_fields), out_cols)
}

/// How one requested aggregation is reassembled from the re-reduced
/// partial columns.
#[derive(Debug, Clone)]
enum FinishPlan {
    /// The final column is the re-reduced partial, renamed to the
    /// caller's output name.
    Carry { part: String },
    /// Mean = global sum / global count, null when the count is zero
    /// (matching the local kernel's all-null-group behaviour).
    Mean { sum: String, cnt: String },
}

/// A decomposition of aggregation requests into associative partials —
/// the "combine" side of the map/combine/shuffle/reduce pattern
/// (arXiv 2010.06312), shared by the distributed map-side-combine
/// group-by (`ops::dist::dist_groupby_partial`) and the streaming
/// pipeline's stateful `keyed_aggregate` stage.
///
/// The lifecycle is `partial → (merge…) → finish`:
///
/// 1. [`partial_specs`](Self::partial_specs) aggregates raw rows into
///    one partial row per group (`Sum`/`Count`/`Min`/`Max` columns;
///    `Mean` is carried as a sum + count pair, interned so overlapping
///    requests share one column);
/// 2. any number of partial tables (from other ranks, or from earlier
///    stream batches) merge by concatenation + re-grouping with
///    [`reduce_specs`](Self::reduce_specs) — each reduce writes back to
///    the same column name, so merging is closed and can repeat
///    (`fold` is the streaming form);
/// 3. [`finish`](Self::finish) reassembles the caller's requested
///    layout, deriving `Mean` from the sum/count pair.
///
/// `Std`/`Var`/`First`/`Last` do not decompose over this partial set
/// and are rejected by [`new`](Self::new).
#[derive(Debug, Clone)]
pub struct PartialAggPlan {
    requested: Vec<AggSpec>,
    partial: Vec<AggSpec>,
    reduce: Vec<AggSpec>,
    plans: Vec<FinishPlan>,
}

impl PartialAggPlan {
    /// Decompose `aggs`; errors on non-decomposable aggregations.
    pub fn new(aggs: &[AggSpec]) -> Result<PartialAggPlan> {
        let mut partial: Vec<AggSpec> = Vec::new();
        let mut refine: Vec<Agg> = Vec::new(); // parallel to `partial`
        let mut index: HashMap<(String, &'static str), String> = HashMap::new();
        // Intern one partial column, shared across requests: overlapping
        // specs (e.g. `Sum(v)` + `Mean(v)` + `Count(v)`) compute and
        // ship each distinct `(column, partial)` exactly once.
        let mut intern = |column: &str, kind: Agg, reduce: Agg| -> String {
            let slot = (column.to_string(), kind.name());
            if let Some(name) = index.get(&slot) {
                return name.clone();
            }
            let name = format!("__p{}_{}", partial.len(), kind.name());
            index.insert(slot, name.clone());
            partial.push(AggSpec::named(column, kind, name.clone()));
            refine.push(reduce);
            name
        };
        let mut plans: Vec<FinishPlan> = Vec::with_capacity(aggs.len());
        for spec in aggs {
            let plan = match spec.agg {
                Agg::Sum => FinishPlan::Carry { part: intern(&spec.column, Agg::Sum, Agg::Sum) },
                Agg::Count => {
                    FinishPlan::Carry { part: intern(&spec.column, Agg::Count, Agg::Sum) }
                }
                Agg::Min => FinishPlan::Carry { part: intern(&spec.column, Agg::Min, Agg::Min) },
                Agg::Max => FinishPlan::Carry { part: intern(&spec.column, Agg::Max, Agg::Max) },
                Agg::Mean => FinishPlan::Mean {
                    sum: intern(&spec.column, Agg::Sum, Agg::Sum),
                    cnt: intern(&spec.column, Agg::Count, Agg::Sum),
                },
                other => bail!(
                    "{} does not decompose into partial aggregates; \
                     use the full-shuffle group-by",
                    other.name()
                ),
            };
            plans.push(plan);
        }
        let reduce: Vec<AggSpec> = partial
            .iter()
            .zip(&refine)
            .map(|(p, agg)| AggSpec::named(p.out_name.clone(), *agg, p.out_name.clone()))
            .collect();
        Ok(PartialAggPlan { requested: aggs.to_vec(), partial, reduce, plans })
    }

    /// Specs that turn raw rows into one partial row per group.
    pub fn partial_specs(&self) -> &[AggSpec] {
        &self.partial
    }

    /// Specs that merge concatenated partial tables (each writes back
    /// to its own column name, so reducing is closed under repetition).
    pub fn reduce_specs(&self) -> &[AggSpec] {
        &self.reduce
    }

    /// Fold one raw batch into an optional running partial state (the
    /// streaming form): aggregate the batch to partials, then merge
    /// with the previous state by concat + re-reduce.
    pub fn fold(&self, state: Option<Table>, batch: &Table, keys: &[&str]) -> Result<Table> {
        let batch_partial = groupby_aggregate(batch, keys, &self.partial)?;
        match state {
            None => Ok(batch_partial),
            Some(prev) => {
                let cat = Table::concat_tables(&[&prev, &batch_partial])?;
                groupby_aggregate(&cat, keys, &self.reduce)
            }
        }
    }

    /// Reassemble the fully-reduced partial table `combined` into the
    /// caller's requested layout: key columns, then one column per
    /// requested aggregation, named exactly as the one-shot local
    /// kernel would name it.
    pub fn finish(&self, keys: &[&str], combined: &Table) -> Result<Table> {
        let mut fields: Vec<Field> = Vec::new();
        let mut cols: Vec<Array> = Vec::new();
        for k in keys {
            let a = combined.column_by_name(k)?;
            fields.push(Field::new(*k, a.data_type()));
            cols.push(a.clone());
        }
        for (spec, plan) in self.requested.iter().zip(&self.plans) {
            match plan {
                FinishPlan::Carry { part } => {
                    let a = combined.column_by_name(part)?;
                    fields.push(Field::new(spec.out_name.clone(), a.data_type()));
                    cols.push(a.clone());
                }
                FinishPlan::Mean { sum, cnt } => {
                    let s = combined.column_by_name(sum)?;
                    let c = combined.column_by_name(cnt)?;
                    let vals: Vec<Option<f64>> = (0..combined.num_rows())
                        .map(|i| match (s.f64_at(i), c.f64_at(i)) {
                            (Some(sv), Some(cv)) if cv > 0.0 => Some(sv / cv),
                            _ => None,
                        })
                        .collect();
                    fields.push(Field::new(spec.out_name.clone(), DataType::Float64));
                    cols.push(Array::from_opt_f64(vals));
                }
            }
        }
        Table::new(Schema::new(fields), cols)
    }
}

/// Whole-table aggregation (no keys): one output row.
pub fn aggregate(table: &Table, aggs: &[AggSpec]) -> Result<Table> {
    // Reuse the grouped path with a constant key, then drop it.
    let tmp = table.with_column("__all", Array::from_i64(vec![0; table.num_rows()]))?;
    if table.num_rows() == 0 {
        // groupby of empty input yields zero groups; synthesise one null row
        let mut fields = Vec::new();
        let mut cols = Vec::new();
        for spec in aggs {
            let src = table.column_by_name(&spec.column)?;
            let ty = spec.agg.out_type(src.data_type())?;
            fields.push(Field::new(spec.out_name.clone(), ty));
            let mut b = ArrayBuilder::with_capacity(ty, 1);
            if spec.agg == Agg::Count {
                b.push_i64(0);
            } else {
                b.push_null();
            }
            cols.push(b.finish());
        }
        return Table::new(Schema::new(fields), cols);
    }
    let g = groupby_aggregate(&tmp, &["__all"], aggs)?;
    g.drop_columns(&["__all"])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::table::Scalar;

    fn t() -> Table {
        Table::from_columns(vec![
            ("g", Array::from_strs(&["a", "b", "a", "b", "a"])),
            ("x", Array::from_opt_i64(vec![Some(1), Some(2), Some(3), None, Some(5)])),
            ("y", Array::from_f64(vec![1.0, 2.0, 3.0, 4.0, 5.0])),
        ])
        .unwrap()
    }

    #[test]
    fn sums_and_counts() {
        let g = groupby_aggregate(
            &t(),
            &["g"],
            &[AggSpec::new("x", Agg::Sum), AggSpec::new("x", Agg::Count)],
        )
        .unwrap();
        assert_eq!(g.num_rows(), 2);
        // first-seen order: a then b
        assert_eq!(g.cell(0, 0), Scalar::Utf8("a".into()));
        assert_eq!(g.cell(0, 1), Scalar::Int64(9)); // 1+3+5
        assert_eq!(g.cell(0, 2), Scalar::Int64(3));
        assert_eq!(g.cell(1, 1), Scalar::Int64(2)); // null skipped
        assert_eq!(g.cell(1, 2), Scalar::Int64(1));
    }

    #[test]
    fn moments() {
        let g = groupby_aggregate(
            &t(),
            &["g"],
            &[
                AggSpec::new("y", Agg::Mean),
                AggSpec::new("y", Agg::Var),
                AggSpec::new("y", Agg::Std),
            ],
        )
        .unwrap();
        // group a: y = 1,3,5 → mean 3, var 8/3
        assert_eq!(g.cell(0, 1), Scalar::Float64(3.0));
        let var = g.cell(0, 2).as_f64().unwrap();
        assert!((var - 8.0 / 3.0).abs() < 1e-12);
        let std = g.cell(0, 3).as_f64().unwrap();
        assert!((std - (8.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn min_max_first_last() {
        let g = groupby_aggregate(
            &t(),
            &["g"],
            &[
                AggSpec::new("x", Agg::Min),
                AggSpec::new("x", Agg::Max),
                AggSpec::new("y", Agg::First),
                AggSpec::new("y", Agg::Last),
            ],
        )
        .unwrap();
        assert_eq!(g.cell(0, 1), Scalar::Int64(1));
        assert_eq!(g.cell(0, 2), Scalar::Int64(5));
        assert_eq!(g.cell(1, 3), Scalar::Float64(2.0));
        assert_eq!(g.cell(1, 4), Scalar::Float64(4.0));
    }

    #[test]
    fn string_min_max() {
        let g = groupby_aggregate(
            &t(),
            &["g"],
            &[AggSpec::new("g", Agg::Min), AggSpec::new("g", Agg::Max)],
        )
        .unwrap();
        assert_eq!(g.cell(0, 1), Scalar::Utf8("a".into()));
    }

    #[test]
    fn null_keys_form_a_group() {
        let tbl = Table::from_columns(vec![
            ("k", Array::from_opt_i64(vec![None, Some(1), None])),
            ("v", Array::from_i64(vec![10, 20, 30])),
        ])
        .unwrap();
        let g = groupby_aggregate(&tbl, &["k"], &[AggSpec::new("v", Agg::Sum)]).unwrap();
        assert_eq!(g.num_rows(), 2);
        assert_eq!(g.cell(0, 0), Scalar::Null);
        assert_eq!(g.cell(0, 1), Scalar::Int64(40));
    }

    #[test]
    fn whole_table_aggregate() {
        let a = aggregate(&t(), &[AggSpec::new("y", Agg::Sum), AggSpec::new("x", Agg::Count)]).unwrap();
        assert_eq!(a.num_rows(), 1);
        assert_eq!(a.cell(0, 0), Scalar::Float64(15.0));
        assert_eq!(a.cell(0, 1), Scalar::Int64(4));
        // empty input
        let e = aggregate(&t().slice(0, 0), &[AggSpec::new("x", Agg::Count), AggSpec::new("y", Agg::Sum)])
            .unwrap();
        assert_eq!(e.cell(0, 0), Scalar::Int64(0));
        assert_eq!(e.cell(0, 1), Scalar::Null);
    }

    #[test]
    fn type_errors() {
        assert!(groupby_aggregate(&t(), &["g"], &[AggSpec::new("g", Agg::Sum)]).is_err());
        assert!(groupby_aggregate(&t(), &[], &[AggSpec::new("x", Agg::Sum)]).is_err());
    }

    #[test]
    fn partial_plan_interns_overlapping_requests() {
        let plan = PartialAggPlan::new(&[
            AggSpec::new("y", Agg::Sum),
            AggSpec::new("y", Agg::Mean),
            AggSpec::new("y", Agg::Count),
        ])
        .unwrap();
        // mean reuses the sum and count partials: 2 columns, not 4
        assert_eq!(plan.partial_specs().len(), 2);
        assert_eq!(plan.reduce_specs().len(), 2);
    }

    #[test]
    fn partial_plan_rejects_non_decomposable() {
        for agg in [Agg::Std, Agg::Var, Agg::First, Agg::Last] {
            assert!(PartialAggPlan::new(&[AggSpec::new("y", agg)]).is_err(), "{agg:?}");
        }
    }

    #[test]
    fn folding_batches_matches_one_shot_groupby() {
        let aggs = [
            AggSpec::new("y", Agg::Sum),
            AggSpec::new("y", Agg::Mean),
            AggSpec::new("x", Agg::Count),
            AggSpec::new("x", Agg::Min),
            AggSpec::new("y", Agg::Max),
        ];
        let full = t();
        let want = groupby_aggregate(&full, &["g"], &aggs).unwrap();
        let plan = PartialAggPlan::new(&aggs).unwrap();
        // fold the table through in three uneven stream batches
        let mut state = None;
        for (start, len) in [(0usize, 2usize), (2, 1), (3, 2)] {
            state = Some(plan.fold(state, &full.slice(start, len), &["g"]).unwrap());
        }
        let got = plan.finish(&["g"], &state.unwrap()).unwrap();
        // same groups in first-seen order, same column names and values
        assert_eq!(got.schema().names(), want.schema().names());
        let canon = |t: &Table| {
            let mut rows: Vec<String> =
                (0..t.num_rows()).map(|i| format!("{:?}", t.row(i))).collect();
            rows.sort();
            rows
        };
        assert_eq!(canon(&got), canon(&want));
    }
}
