//! The UNOMT application (paper §4): CANDLE drug-response feature
//! engineering + distributed deep learning, end to end, in one program.
//!
//! * [`config`] — synthetic workload dimensions (NCI60-analog).
//! * [`datagen`] — the three raw datasets with the paper's schemas.
//! * [`pipeline`] — the Figs 8–11 operator pipeline, sequential /
//!   BSP-distributed / async-task-graph variants.

pub mod config;
pub mod datagen;
pub mod pipeline;

pub use config::UnomtConfig;
pub use pipeline::{run_dist, run_local, PipelineStats};
