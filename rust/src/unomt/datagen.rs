//! Synthetic NCI60-analog data generators (DESIGN.md §3 substitution).
//!
//! Three raw datasets with the paper's schemas and dirt:
//! * **drug response** (Fig 8 input): source centre, symbol-polluted
//!   drug id, cell line, log-concentration, growth + two junk columns
//!   that the pipeline's column filter must drop;
//! * **drug features** (Fig 9 input): two sub-tables (descriptors,
//!   fingerprints) keyed by *clean* drug id, covering a configurable
//!   fraction of drugs;
//! * **RNA-seq** (Fig 10 input): symbol-polluted cell ids, duplicated
//!   rows, numeric expression features with nulls.
//!
//! Everything is deterministic in the config seed; rank-sharded
//! generation (`response_shard`) partitions rows without materialising
//! the global table.

use super::config::UnomtConfig;
use crate::table::{Array, Table};
use crate::util::rng::Rng;
use anyhow::Result;

/// Clean drug id (what the metadata tables use).
pub fn drug_id(i: usize) -> String {
    format!("NSC{i:05}")
}

/// Dirty drug id as it appears in raw response files ("NSC.00123").
fn dirty_drug_id(i: usize, rng: &mut Rng) -> String {
    let sep = match rng.gen_range(3) {
        0 => ".",
        1 => "-",
        _ => "_",
    };
    format!("NSC{sep}{i:05}")
}

/// Cell line id ("CCL_07"); raw RNA files pollute it with a suffix.
pub fn cell_id(i: usize) -> String {
    format!("CCL_{i:03}")
}

/// One rank's shard of the drug-response table (`world = 1` gives the
/// whole table). Row counts split as evenly as `Table::split`.
pub fn response_shard(cfg: &UnomtConfig, rank: usize, world: usize) -> Result<Table> {
    let base = cfg.n_response / world;
    let extra = cfg.n_response % world;
    let n = base + usize::from(rank < extra);
    // Independent stream per rank (same global distribution).
    let mut rng = Rng::new(cfg.seed ^ 0xD0D0).fork(rank as u64);

    let mut source = Vec::with_capacity(n);
    let mut drug = Vec::with_capacity(n);
    let mut cell = Vec::with_capacity(n);
    let mut conc = Vec::with_capacity(n);
    let mut growth = Vec::with_capacity(n);
    let mut junk_a = Vec::with_capacity(n);
    let mut junk_b = Vec::with_capacity(n);
    let centres = ["NCI60", "CTRP", "GDSC", "CCLE", "gCSI", "NCIPDM"];

    for _ in 0..n {
        let d = rng.usize_in(0, cfg.n_drugs);
        let c = rng.usize_in(0, cfg.n_cell_lines);
        source.push(centres[rng.usize_in(0, centres.len())].to_string());
        drug.push(dirty_drug_id(d, &mut rng));
        cell.push(cell_id(c));
        // log10 molar concentration in [-8, -4]
        let lc = -8.0 + 4.0 * rng.f64();
        conc.push(if rng.bool(cfg.null_frac) { None } else { Some(lc) });
        // growth: dose-dependent sigmoid + drug/cell effects + noise
        let effect = ((d * 31 + c * 17) % 100) as f64 / 100.0;
        let g = 100.0 / (1.0 + (-(lc + 6.0) * 2.0).exp()) * (0.5 + effect) + 5.0 * rng.normal();
        growth.push(if rng.bool(cfg.null_frac) { None } else { Some(g) });
        junk_a.push(rng.gen_range(1000) as i64);
        junk_b.push(rng.ascii_lower(4));
    }

    Table::from_columns(vec![
        ("SOURCE", Array::from_strs(&source)),
        ("DRUG_ID", Array::from_strs(&drug)),
        ("CELLNAME", Array::from_strs(&cell)),
        ("LOG_CONCENTRATION", Array::from_opt_f64(conc)),
        ("GROWTH", Array::from_opt_f64(growth)),
        ("STUDY_ROW", Array::from_i64(junk_a)),
        ("BATCH_TAG", Array::from_strs(&junk_b)),
    ])
}

/// Drug descriptor sub-table (covered drugs only).
pub fn drug_descriptors(cfg: &UnomtConfig) -> Result<Table> {
    let mut rng = Rng::new(cfg.seed ^ 0xDE5C);
    covered_drug_features(cfg, &mut rng, cfg.n_descriptors, "DD")
}

/// Drug fingerprint sub-table (covered drugs only).
pub fn drug_fingerprints(cfg: &UnomtConfig) -> Result<Table> {
    let mut rng = Rng::new(cfg.seed ^ 0xF17E);
    covered_drug_features(cfg, &mut rng, cfg.n_fingerprints, "FP")
}

fn covered_drug_features(
    cfg: &UnomtConfig,
    rng: &mut Rng,
    width: usize,
    prefix: &str,
) -> Result<Table> {
    let n_covered = ((cfg.n_drugs as f64) * cfg.drug_coverage).round() as usize;
    let ids: Vec<String> = (0..n_covered).map(drug_id).collect();
    let mut cols: Vec<(String, Array)> = vec![("DRUG_ID".to_string(), Array::from_strs(&ids))];
    for f in 0..width {
        let vals: Vec<Option<f64>> = (0..n_covered)
            .map(|_| {
                if rng.bool(cfg.null_frac) {
                    None
                } else {
                    Some(rng.normal())
                }
            })
            .collect();
        cols.push((format!("{prefix}_{f}"), Array::from_opt_f64(vals)));
    }
    let refs: Vec<(&str, Array)> = cols.iter().map(|(n, a)| (n.as_str(), a.clone())).collect();
    Table::from_columns(refs)
}

/// Raw RNA-seq table: dirty cell ids, duplicates, nulls.
pub fn rna_seq(cfg: &UnomtConfig) -> Result<Table> {
    let mut rng = Rng::new(cfg.seed ^ 0x19A5);
    let n_dups = ((cfg.n_cell_lines as f64) * cfg.dup_frac).ceil() as usize;
    let n = cfg.n_cell_lines + n_dups;

    let mut ids = Vec::with_capacity(n);
    let mut rows: Vec<Vec<Option<f64>>> = (0..cfg.n_rna_features).map(|_| Vec::with_capacity(n)).collect();
    let gen_row = |c: usize, rng: &mut Rng, rows: &mut Vec<Vec<Option<f64>>>| {
        for (f, col) in rows.iter_mut().enumerate() {
            // per-cell deterministic base so duplicates carry equal values
            let base = (((c * 131 + f * 17) % 97) as f64) / 10.0;
            col.push(if rng.bool(cfg.null_frac) { None } else { Some(base) });
        }
    };
    for c in 0..cfg.n_cell_lines {
        // raw files decorate the id: "CCL_007.r1"
        ids.push(format!("{}.r1", cell_id(c)));
        gen_row(c, &mut rng, &mut rows);
    }
    for _ in 0..n_dups {
        let c = rng.usize_in(0, cfg.n_cell_lines);
        ids.push(format!("{}.r1", cell_id(c)));
        // exact duplicate feature rows (no fresh nulls → identical)
        for (f, col) in rows.iter_mut().enumerate() {
            let base = (((c * 131 + f * 17) % 97) as f64) / 10.0;
            col.push(Some(base));
        }
    }

    let mut cols: Vec<(String, Array)> = vec![("CELLNAME".to_string(), Array::from_strs(&ids))];
    for (f, col) in rows.into_iter().enumerate() {
        cols.push((format!("RNA_{f}"), Array::from_opt_f64(col)));
    }
    let refs: Vec<(&str, Array)> = cols.iter().map(|(n, a)| (n.as_str(), a.clone())).collect();
    Table::from_columns(refs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> UnomtConfig {
        UnomtConfig { n_response: 500, ..Default::default() }
    }

    #[test]
    fn response_schema_and_dirt() {
        let t = response_shard(&cfg(), 0, 1).unwrap();
        assert_eq!(t.num_rows(), 500);
        assert_eq!(t.num_columns(), 7);
        // ids are dirty (contain a separator symbol)
        let id = t.cell(0, 1).to_string();
        assert!(id.contains('.') || id.contains('-') || id.contains('_'));
        // some nulls injected
        assert!(t.column_by_name("GROWTH").unwrap().null_count() > 0);
    }

    #[test]
    fn sharding_partitions_rows() {
        let total: usize = (0..3)
            .map(|r| response_shard(&cfg(), r, 3).unwrap().num_rows())
            .sum();
        assert_eq!(total, 500);
        // shards differ (independent streams)
        let a = response_shard(&cfg(), 0, 3).unwrap();
        let b = response_shard(&cfg(), 1, 3).unwrap();
        assert_ne!(a.cell(0, 1), b.cell(0, 1));
    }

    #[test]
    fn metadata_coverage() {
        let d = drug_descriptors(&cfg()).unwrap();
        assert_eq!(d.num_rows(), (1006f64 * 0.9).round() as usize);
        assert_eq!(d.num_columns(), 1 + 20);
        let f = drug_fingerprints(&cfg()).unwrap();
        assert_eq!(f.num_columns(), 1 + 20);
    }

    #[test]
    fn rna_has_duplicates() {
        let r = rna_seq(&cfg()).unwrap();
        assert!(r.num_rows() > 60);
        let dedup = crate::ops::local::drop_duplicates(&r, Some(&["CELLNAME"])).unwrap();
        assert_eq!(dedup.num_rows(), 60);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = response_shard(&cfg(), 0, 2).unwrap();
        let b = response_shard(&cfg(), 0, 2).unwrap();
        assert_eq!(a, b);
    }
}
