//! UNOMT workload configuration.
//!
//! The paper uses NCI60 (1006 drugs) + gCSI and 2.5M response samples;
//! we have no access to those, so the generators in
//! [`super::datagen`] synthesise datasets with the same schema, the
//! same dirt (symbol-polluted ids, duplicates, nulls) and configurable
//! cardinalities/selectivities (DESIGN.md §3).

/// Synthetic UNOMT dataset dimensions.
#[derive(Debug, Clone)]
pub struct UnomtConfig {
    /// Drug-response rows (the paper's 2.5M; default scaled down).
    pub n_response: usize,
    /// Distinct drugs (paper: 1006 from NCI60).
    pub n_drugs: usize,
    /// Distinct cell lines (NCI60: 60).
    pub n_cell_lines: usize,
    /// Drug descriptor feature count (first metadata sub-table).
    pub n_descriptors: usize,
    /// Drug fingerprint feature count (second metadata sub-table).
    pub n_fingerprints: usize,
    /// RNA-seq feature count per cell line.
    pub n_rna_features: usize,
    /// Fraction of drugs present in the metadata tables (drives the
    /// isin/intersect selectivity of Fig 11).
    pub drug_coverage: f64,
    /// Fraction of null cells injected into raw numeric columns.
    pub null_frac: f64,
    /// Fraction of RNA rows duplicated (exercises drop_duplicates).
    pub dup_frac: f64,
    pub seed: u64,
}

impl Default for UnomtConfig {
    fn default() -> Self {
        UnomtConfig {
            n_response: 20_000,
            n_drugs: 1006, // NCI60
            n_cell_lines: 60,
            n_descriptors: 20,
            n_fingerprints: 20,
            n_rna_features: 23,
            drug_coverage: 0.9,
            null_frac: 0.01,
            dup_frac: 0.05,
            seed: 42,
        }
    }
}

impl UnomtConfig {
    /// Engineered feature width = descriptors + fingerprints + RNA +
    /// concentration (must equal the model's `d_in`).
    pub fn feature_width(&self) -> usize {
        self.n_descriptors + self.n_fingerprints + self.n_rna_features + 1
    }

    /// Scale row counts (for bench sweeps).
    pub fn with_rows(mut self, n: usize) -> Self {
        self.n_response = n;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_model_d_in() {
        // The default AOT artifact is lowered with d_in = 64.
        assert_eq!(UnomtConfig::default().feature_width(), 64);
    }
}
