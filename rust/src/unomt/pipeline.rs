//! The UNOMT feature-engineering pipeline (paper Figs 8–11).
//!
//! Four stages, exactly the operator sequence §4.3 describes:
//! * **Fig 8 — drug response**: column filter (Project) → map-clean the
//!   symbol-polluted drug ids → dropna → min-max scale the numeric
//!   columns → fully numeric.
//! * **Fig 9 — drug features**: inner-join the descriptor and
//!   fingerprint sub-tables on drug id → cast numeric → fill nulls.
//! * **Fig 10 — RNA-seq**: map-clean cell ids → drop_duplicates → scale
//!   → cast numeric → fill nulls.
//! * **Fig 11 — assembly**: unique response drugs, isin-filter against
//!   the metadata drug set (the "common drugs" AND), then join response
//!   ⋈ drug-features ⋈ RNA and project to the model's feature layout
//!   `[LOG_CONCENTRATION, DD_*, FP_*, RNA_*, GROWTH]`.
//!
//! `run_local` executes sequentially (the Pandas/PyCylon-1-core role);
//! `run_dist` executes the same code on each rank's shard — pleasingly
//! parallel except the **distributed drop_duplicates** (the one global
//! operator, §4.3) — the metadata tables are replicated, so the joins
//! are map-side (broadcast) joins. `build_taskgraph` compiles the same
//! pipeline into a task DAG for the async central-scheduler baseline
//! (the Modin role).

use super::config::UnomtConfig;
use super::datagen;
use crate::comm::Communicator;
use crate::exec::asynch::{TaskGraph, TaskId};
use crate::ops::dist;
use crate::ops::local::{self, DropNaHow, JoinAlgorithm, JoinType};
use crate::table::{Scalar, Table};
use anyhow::{bail, Result};

/// Per-stage row counts + CPU timing.
#[derive(Debug, Clone, Default)]
pub struct StageStat {
    pub name: &'static str,
    pub rows_in: usize,
    pub rows_out: usize,
    pub cpu_seconds: f64,
}

/// Pipeline execution report.
#[derive(Debug, Clone, Default)]
pub struct PipelineStats {
    pub stages: Vec<StageStat>,
}

impl PipelineStats {
    fn record<T>(&mut self, name: &'static str, rows_in: usize, f: impl FnOnce() -> Result<T>) -> Result<T>
    where
        T: RowCounted,
    {
        let sw = crate::util::time::CpuStopwatch::start();
        let out = f()?;
        self.stages.push(StageStat {
            name,
            rows_in,
            rows_out: out.rows(),
            cpu_seconds: sw.elapsed().as_secs_f64(),
        });
        Ok(out)
    }

    pub fn total_cpu_seconds(&self) -> f64 {
        self.stages.iter().map(|s| s.cpu_seconds).sum()
    }
}

/// Row-count view used by the stats recorder.
pub trait RowCounted {
    fn rows(&self) -> usize;
}

impl RowCounted for Table {
    fn rows(&self) -> usize {
        self.num_rows()
    }
}

// ---- stages ---------------------------------------------------------------

/// Fig 8: raw response → clean numeric response.
pub fn clean_response(raw: &Table) -> Result<Table> {
    // Project: drop the junk columns the raw feed carries.
    let t = raw.select_columns(&["DRUG_ID", "CELLNAME", "LOG_CONCENTRATION", "GROWTH"])?;
    // Map: strip the symbols from drug ids ("NSC.00123" → "NSC00123").
    let t = local::map_column_utf8(&t, "DRUG_ID", |s| {
        s.chars().filter(|c| !matches!(c, '.' | '-' | '_')).collect()
    })?;
    // dropna on the numeric columns (paper: not_null / dropna).
    let t = local::dropna(&t, Some(&["LOG_CONCENTRATION", "GROWTH"]), DropNaHow::Any)?;
    // Scale numeric values (the Scikit-learn MinMaxScaler role).
    let (t, _) = local::min_max_scale(&t, &["LOG_CONCENTRATION", "GROWTH"])?;
    Ok(t)
}

/// Fig 9: descriptor ⋈ fingerprint metadata → numeric drug features.
pub fn drug_feature_table(descriptors: &Table, fingerprints: &Table) -> Result<Table> {
    let joined = local::join(
        descriptors,
        fingerprints,
        &["DRUG_ID"],
        &["DRUG_ID"],
        JoinType::Inner,
        JoinAlgorithm::Hash,
    )?;
    let t = joined.drop_columns(&["DRUG_ID_r"])?;
    // Cast to numeric + fill the injected nulls (features must be dense).
    let t = local::to_numeric_table(&t)?;
    let fills: Vec<(&str, Scalar)> = t
        .schema()
        .names()
        .iter()
        .filter(|n| **n != "DRUG_ID")
        .map(|n| (*n, Scalar::Float64(0.0)))
        .collect();
    local::fillna(&t, &fills)
}

/// Fig 10: raw RNA-seq → clean deduplicated numeric features.
pub fn clean_rna(raw: &Table) -> Result<Table> {
    // Map: strip the ".r1" decoration from cell ids.
    let t = local::map_column_utf8(raw, "CELLNAME", |s| {
        s.split('.').next().unwrap_or(s).to_string()
    })?;
    // drop duplicate cell lines (paper: drop-duplicate operator).
    let t = local::drop_duplicates(&t, Some(&["CELLNAME"]))?;
    // Scale the expression features.
    let feature_names: Vec<String> = t
        .schema()
        .names()
        .iter()
        .filter(|n| n.starts_with("RNA_"))
        .map(|s| s.to_string())
        .collect();
    let refs: Vec<&str> = feature_names.iter().map(|s| s.as_str()).collect();
    let (t, _) = local::min_max_scale(&t, &refs)?;
    let fills: Vec<(&str, Scalar)> = refs.iter().map(|n| (*n, Scalar::Float64(0.0))).collect();
    local::fillna(&t, &fills)
}

/// Fig 11: assemble the final drug-response training table.
///
/// Output columns: `LOG_CONCENTRATION, DD_*, FP_*, RNA_*, GROWTH`.
pub fn assemble(response: &Table, drug_features: &Table, rna: &Table) -> Result<Table> {
    // Common drugs: response drugs ∩ metadata drugs (the paper's isin +
    // AND step).
    let drug_ids = drug_features.column_by_name("DRUG_ID")?;
    let filtered = local::filter_isin(response, "DRUG_ID", drug_ids)?;
    let cells = rna.column_by_name("CELLNAME")?;
    let filtered = local::filter_isin(&filtered, "CELLNAME", cells)?;

    // response ⋈ drug features on DRUG_ID.
    let j1 = local::join(
        &filtered,
        drug_features,
        &["DRUG_ID"],
        &["DRUG_ID"],
        JoinType::Inner,
        JoinAlgorithm::Hash,
    )?;
    // ⋈ RNA on CELLNAME.
    let j2 = local::join(&j1, rna, &["CELLNAME"], &["CELLNAME"], JoinType::Inner, JoinAlgorithm::Hash)?;

    // Project to the model feature layout (features..., label last).
    let mut names: Vec<String> = vec!["LOG_CONCENTRATION".into()];
    for n in j2.schema().names() {
        if n.starts_with("DD_") || n.starts_with("FP_") || n.starts_with("RNA_") {
            names.push(n.to_string());
        }
    }
    names.push("GROWTH".into());
    let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    j2.select_columns(&refs)
}

// ---- drivers ---------------------------------------------------------------

/// Sequential run over the full synthetic dataset.
pub fn run_local(cfg: &UnomtConfig) -> Result<(Table, PipelineStats)> {
    let mut stats = PipelineStats::default();
    let raw = stats.record("gen_response", 0, || datagen::response_shard(cfg, 0, 1))?;
    let desc = datagen::drug_descriptors(cfg)?;
    let fp = datagen::drug_fingerprints(cfg)?;
    let rna_raw = datagen::rna_seq(cfg)?;

    let response = stats.record("clean_response", raw.num_rows(), || clean_response(&raw))?;
    let features =
        stats.record("drug_features", desc.num_rows(), || drug_feature_table(&desc, &fp))?;
    let rna = stats.record("clean_rna", rna_raw.num_rows(), || clean_rna(&rna_raw))?;
    let out = stats.record("assemble", response.num_rows(), || {
        assemble(&response, &features, &rna)
    })?;
    Ok((out, stats))
}

/// Distributed (BSP) run: this rank's partition of the engineered table.
///
/// Metadata is replicated (generated identically per rank) so the joins
/// are map-side; the global step is the distributed drop_duplicates
/// (the paper's "distributed unique operator", §4.3).
pub fn run_dist<C: Communicator + ?Sized>(
    comm: &mut C,
    cfg: &UnomtConfig,
) -> Result<(Table, PipelineStats)> {
    let mut stats = PipelineStats::default();
    let (rank, world) = (comm.rank(), comm.world_size());
    let raw = stats.record("gen_response", 0, || datagen::response_shard(cfg, rank, world))?;
    let desc = datagen::drug_descriptors(cfg)?;
    let fp = datagen::drug_fingerprints(cfg)?;
    let rna_raw = datagen::rna_seq(cfg)?;

    let response = stats.record("clean_response", raw.num_rows(), || clean_response(&raw))?;
    // Global dedup of identical measurements across ranks (exercises
    // the shuffle path; the paper calls this step out explicitly).
    let n_in = response.num_rows();
    let response = {
        let sw = crate::util::time::CpuStopwatch::start();
        let out = dist::dist_drop_duplicates(
            comm,
            &response,
            Some(&["DRUG_ID", "CELLNAME", "LOG_CONCENTRATION"]),
        )?;
        stats.stages.push(StageStat {
            name: "dist_dedup",
            rows_in: n_in,
            rows_out: out.num_rows(),
            cpu_seconds: sw.elapsed().as_secs_f64(),
        });
        out
    };
    let features =
        stats.record("drug_features", desc.num_rows(), || drug_feature_table(&desc, &fp))?;
    let rna = stats.record("clean_rna", rna_raw.num_rows(), || clean_rna(&rna_raw))?;
    let out = stats.record("assemble", response.num_rows(), || {
        assemble(&response, &features, &rna)
    })?;
    Ok((out, stats))
}

/// Compile the pipeline into a task DAG over `nparts` partitions for
/// the async central-scheduler baseline (Modin role in Figs 12–14).
///
/// Returns the graph and the per-partition output task ids.
pub fn build_taskgraph(cfg: &UnomtConfig, nparts: usize) -> Result<(TaskGraph, Vec<TaskId>)> {
    if nparts == 0 {
        bail!("nparts must be > 0");
    }
    let mut g = TaskGraph::new();
    let cfg = cfg.clone();

    // Metadata tasks (single partition each, like Modin's small frames).
    let cfg_d = cfg.clone();
    let desc = g.source("gen_descriptors", move || datagen::drug_descriptors(&cfg_d));
    let cfg_f = cfg.clone();
    let fp = g.source("gen_fingerprints", move || datagen::drug_fingerprints(&cfg_f));
    let features = g.add("drug_features", vec![desc, fp], |ins| {
        drug_feature_table(ins[0], ins[1])
    });
    let cfg_r = cfg.clone();
    let rna_raw = g.source("gen_rna", move || datagen::rna_seq(&cfg_r));
    let rna = g.add("clean_rna", vec![rna_raw], |ins| clean_rna(ins[0]));

    // Per-partition generate + clean.
    let mut cleaned_parts = Vec::with_capacity(nparts);
    for p in 0..nparts {
        let cfg_p = cfg.clone();
        let src = g.source(format!("gen_response-{p}"), move || {
            datagen::response_shard(&cfg_p, p, nparts)
        });
        cleaned_parts.push(g.add(format!("clean_response-{p}"), vec![src], |ins| {
            clean_response(ins[0])
        }));
    }

    // Full-axis materialisation: the sklearn-style scaling inside
    // clean_response needs whole-column statistics, which forces
    // Modin to materialise ALL partitions into one frame and re-split
    // (the paper: "it cannot go back-and-forth between the Pandas data
    // structure... caused some of these operations to be relatively
    // slower for Modin"). Every byte passes the object store twice.
    let materialized = g.add("full_axis_materialize", cleaned_parts.clone(), |ins| {
        Table::concat_tables(&ins.to_vec())
    });
    let mut resplit = Vec::with_capacity(nparts);
    for p in 0..nparts {
        resplit.push(g.add(format!("resplit-{p}"), vec![materialized], move |ins| {
            Ok(ins[0].split(nparts).swap_remove(p))
        }));
    }

    // Per-partition assembly against the (store-routed) metadata.
    let mut outs = Vec::with_capacity(nparts);
    for (p, part) in resplit.into_iter().enumerate() {
        let out = g.add(format!("assemble-{p}"), vec![part, features, rna], |ins| {
            assemble(ins[0], ins[1], ins[2])
        });
        outs.push(out);
    }
    Ok((g, outs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{spawn_world, LinkProfile};
    use crate::exec::asynch::{run_async, AsyncCost};

    fn cfg() -> UnomtConfig {
        UnomtConfig { n_response: 2000, ..Default::default() }
    }

    #[test]
    fn local_pipeline_produces_model_layout() {
        let (out, stats) = run_local(&cfg()).unwrap();
        assert_eq!(out.num_columns(), cfg().feature_width() + 1);
        assert_eq!(out.schema().names()[0], "LOG_CONCENTRATION");
        assert_eq!(*out.schema().names().last().unwrap(), "GROWTH");
        // dense numeric output
        for c in 0..out.num_columns() {
            assert_eq!(out.column(c).null_count(), 0, "column {c} has nulls");
            assert!(out.column(c).data_type().is_numeric());
        }
        // rows were filtered but most survive (coverage 0.9)
        assert!(out.num_rows() > 1000 && out.num_rows() < 2000);
        assert_eq!(stats.stages.len(), 5);
        assert!(stats.total_cpu_seconds() > 0.0);
    }

    #[test]
    fn scaled_columns_are_unit_range() {
        let (out, _) = run_local(&cfg()).unwrap();
        for name in ["LOG_CONCENTRATION", "GROWTH"] {
            let col = out.column_by_name(name).unwrap();
            for i in 0..col.len() {
                let v = col.f64_at(i).unwrap();
                assert!((0.0..=1.0).contains(&v), "{name}[{i}] = {v}");
            }
        }
    }

    #[test]
    fn dist_pipeline_matches_local_union() {
        let w = 3;
        let parts = spawn_world(w, LinkProfile::zero(), move |_, comm| {
            run_dist(comm, &cfg()).map(|(t, _)| t)
        })
        .unwrap();
        let dist_total: usize = parts.iter().map(|t| t.num_rows()).sum();
        // local run on the union of shards (same generator streams):
        // response shards are rank-seeded, so regenerate via world=1 of
        // each shard and assemble — instead compare against the sum of
        // locally-assembled shards (dedup rarely fires on random data).
        let mut local_total = 0;
        for r in 0..w {
            let raw = datagen::response_shard(&cfg(), r, w).unwrap();
            let response = clean_response(&raw).unwrap();
            let features = drug_feature_table(
                &datagen::drug_descriptors(&cfg()).unwrap(),
                &datagen::drug_fingerprints(&cfg()).unwrap(),
            )
            .unwrap();
            let rna = clean_rna(&datagen::rna_seq(&cfg()).unwrap()).unwrap();
            local_total += assemble(&response, &features, &rna).unwrap().num_rows();
        }
        assert_eq!(dist_total, local_total);
    }

    #[test]
    fn async_taskgraph_matches_local() {
        let (mut g, outs) = build_taskgraph(&cfg(), 2).unwrap();
        let run = run_async(&mut g, 2, &AsyncCost::default()).unwrap();
        let async_total: usize = outs.iter().map(|id| run.outputs[id.0].num_rows()).sum();
        // Oracle: the same shards assembled sequentially (shard RNG
        // streams differ from the world=1 stream, so compare per-shard).
        let features = drug_feature_table(
            &datagen::drug_descriptors(&cfg()).unwrap(),
            &datagen::drug_fingerprints(&cfg()).unwrap(),
        )
        .unwrap();
        let rna = clean_rna(&datagen::rna_seq(&cfg()).unwrap()).unwrap();
        let mut oracle_total = 0;
        for p in 0..2 {
            let raw = datagen::response_shard(&cfg(), p, 2).unwrap();
            let response = clean_response(&raw).unwrap();
            oracle_total += assemble(&response, &features, &rna).unwrap().num_rows();
        }
        assert_eq!(async_total, oracle_total);
        assert!(run.sim.wall_seconds > 0.0);
    }

    #[test]
    fn feature_width_contract() {
        // The engineered width must equal the default model's d_in.
        let (out, _) = run_local(&cfg()).unwrap();
        assert_eq!(out.num_columns() - 1, 64);
    }
}
