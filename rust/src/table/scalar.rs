//! Data types and scalar values for table columns.
//!
//! The substrate mirrors the slice of the Apache Arrow type system the
//! HPTMT paper's workloads actually exercise: 64-bit integers, 64-bit
//! floats, UTF-8 strings and booleans, all nullable.

use std::fmt;

/// Physical type of a column.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DataType {
    Int64,
    Float64,
    Utf8,
    Bool,
    /// Milliseconds since the Unix epoch, UTC (`i64` physical layout).
    Timestamp,
}

impl DataType {
    /// Short lowercase name (used by CSV inference, pretty printing and
    /// the IPC header).
    pub fn name(&self) -> &'static str {
        match self {
            DataType::Int64 => "int64",
            DataType::Float64 => "float64",
            DataType::Utf8 => "utf8",
            DataType::Bool => "bool",
            DataType::Timestamp => "timestamp",
        }
    }

    /// Stable one-byte tag for the IPC wire format. Tag 4 is reserved
    /// for the wire-only dictionary encoding (`ipc::DICT_TAG`).
    pub fn tag(&self) -> u8 {
        match self {
            DataType::Int64 => 0,
            DataType::Float64 => 1,
            DataType::Utf8 => 2,
            DataType::Bool => 3,
            DataType::Timestamp => 5,
        }
    }

    /// Inverse of [`DataType::tag`].
    pub fn from_tag(tag: u8) -> Option<DataType> {
        Some(match tag {
            0 => DataType::Int64,
            1 => DataType::Float64,
            2 => DataType::Utf8,
            3 => DataType::Bool,
            5 => DataType::Timestamp,
            _ => return None,
        })
    }

    /// True when values of this type are numeric (castable to f64).
    pub fn is_numeric(&self) -> bool {
        matches!(self, DataType::Int64 | DataType::Float64)
    }
}

impl fmt::Display for DataType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A single (possibly null) cell value.
///
/// `Scalar` is the slow path — operators work on columnar arrays — but it
/// is the convenient currency for filters, literals and test assertions.
#[derive(Debug, Clone, PartialEq)]
pub enum Scalar {
    Null,
    Int64(i64),
    Float64(f64),
    Utf8(String),
    Bool(bool),
    /// Milliseconds since the Unix epoch, UTC.
    Timestamp(i64),
}

impl Scalar {
    pub fn is_null(&self) -> bool {
        matches!(self, Scalar::Null)
    }

    /// The type of the scalar, if it is not null.
    pub fn data_type(&self) -> Option<DataType> {
        Some(match self {
            Scalar::Null => return None,
            Scalar::Int64(_) => DataType::Int64,
            Scalar::Float64(_) => DataType::Float64,
            Scalar::Utf8(_) => DataType::Utf8,
            Scalar::Bool(_) => DataType::Bool,
            Scalar::Timestamp(_) => DataType::Timestamp,
        })
    }

    /// Numeric view (ints widen to f64). None for null / non-numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Scalar::Int64(v) => Some(*v as f64),
            Scalar::Float64(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Scalar::Int64(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Scalar::Utf8(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Scalar::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Milliseconds since epoch for timestamp scalars.
    pub fn as_timestamp(&self) -> Option<i64> {
        match self {
            Scalar::Timestamp(ms) => Some(*ms),
            _ => None,
        }
    }
}

impl fmt::Display for Scalar {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Scalar::Null => write!(f, "null"),
            Scalar::Int64(v) => write!(f, "{v}"),
            Scalar::Float64(v) => write!(f, "{v}"),
            Scalar::Utf8(s) => write!(f, "{s}"),
            Scalar::Bool(b) => write!(f, "{b}"),
            Scalar::Timestamp(ms) => {
                f.write_str(&super::time::format_timestamp_ms(*ms))
            }
        }
    }
}

impl From<i64> for Scalar {
    fn from(v: i64) -> Self {
        Scalar::Int64(v)
    }
}
impl From<f64> for Scalar {
    fn from(v: f64) -> Self {
        Scalar::Float64(v)
    }
}
impl From<&str> for Scalar {
    fn from(v: &str) -> Self {
        Scalar::Utf8(v.to_string())
    }
}
impl From<String> for Scalar {
    fn from(v: String) -> Self {
        Scalar::Utf8(v)
    }
}
impl From<bool> for Scalar {
    fn from(v: bool) -> Self {
        Scalar::Bool(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tag_roundtrip() {
        for dt in [
            DataType::Int64,
            DataType::Float64,
            DataType::Utf8,
            DataType::Bool,
            DataType::Timestamp,
        ] {
            assert_eq!(DataType::from_tag(dt.tag()), Some(dt));
        }
        assert_eq!(DataType::from_tag(42), None);
        // tag 4 stays reserved for the wire-only dict encoding
        assert_eq!(DataType::from_tag(4), None);
    }

    #[test]
    fn timestamp_scalar_displays_iso8601() {
        assert_eq!(Scalar::Timestamp(0).to_string(), "1970-01-01T00:00:00Z");
        assert_eq!(Scalar::Timestamp(0).data_type(), Some(DataType::Timestamp));
        assert_eq!(Scalar::Timestamp(7).as_timestamp(), Some(7));
        assert_eq!(Scalar::Timestamp(7).as_i64(), None, "timestamps are not ints");
        assert!(!DataType::Timestamp.is_numeric());
    }

    #[test]
    fn scalar_views() {
        assert_eq!(Scalar::Int64(3).as_f64(), Some(3.0));
        assert_eq!(Scalar::Float64(2.5).as_f64(), Some(2.5));
        assert_eq!(Scalar::Utf8("x".into()).as_str(), Some("x"));
        assert!(Scalar::Null.is_null());
        assert_eq!(Scalar::Null.data_type(), None);
        assert_eq!(Scalar::Bool(true).as_bool(), Some(true));
    }

    #[test]
    fn scalar_from_conversions() {
        assert_eq!(Scalar::from(1i64), Scalar::Int64(1));
        assert_eq!(Scalar::from("a"), Scalar::Utf8("a".into()));
        assert_eq!(Scalar::from(false), Scalar::Bool(false));
    }
}
