//! The in-memory columnar table: schema + equal-length arrays.
//!
//! `Table` is the local (single-rank) unit the HPTMT operators work on.
//! A distributed table is simply one `Table` per rank plus the
//! communicator that relates them (the paper's "global view").

use super::array::Array;
use super::scalar::Scalar;
use super::schema::{Field, Schema, SchemaRef};
use anyhow::{bail, Result};
use std::sync::Arc;

/// Immutable columnar table.
#[derive(Debug, Clone, PartialEq)]
pub struct Table {
    schema: SchemaRef,
    columns: Vec<Array>,
    nrows: usize,
}

impl Table {
    /// Build from a schema and matching columns.
    pub fn new(schema: Schema, columns: Vec<Array>) -> Result<Table> {
        Self::new_shared(Arc::new(schema), columns)
    }

    /// Build sharing an existing schema allocation.
    pub fn new_shared(schema: SchemaRef, columns: Vec<Array>) -> Result<Table> {
        if schema.len() != columns.len() {
            bail!(
                "schema has {} fields but {} columns supplied",
                schema.len(),
                columns.len()
            );
        }
        let nrows = columns.first().map_or(0, |c| c.len());
        for (f, c) in schema.fields().iter().zip(columns.iter()) {
            if f.data_type != c.data_type() {
                bail!(
                    "column {:?}: schema says {} but array is {}",
                    f.name,
                    f.data_type,
                    c.data_type()
                );
            }
            if c.len() != nrows {
                bail!("ragged table: column {:?} has {} rows, expected {nrows}", f.name, c.len());
            }
        }
        Ok(Table { schema, columns, nrows })
    }

    /// Convenience constructor from (name, array) pairs.
    pub fn from_columns(cols: Vec<(&str, Array)>) -> Result<Table> {
        let fields = cols
            .iter()
            .map(|(n, a)| Field::new(*n, a.data_type()))
            .collect::<Vec<_>>();
        let arrays = cols.into_iter().map(|(_, a)| a).collect();
        Table::new(Schema::new(fields), arrays)
    }

    /// Zero-row table with the given schema.
    pub fn empty(schema: Schema) -> Table {
        let columns = schema.fields().iter().map(|f| Array::empty(f.data_type)).collect();
        Table { schema: Arc::new(schema), columns, nrows: 0 }
    }

    // ---- inspectors ----------------------------------------------------

    pub fn schema(&self) -> &SchemaRef {
        &self.schema
    }

    pub fn num_rows(&self) -> usize {
        self.nrows
    }

    pub fn num_columns(&self) -> usize {
        self.columns.len()
    }

    pub fn columns(&self) -> &[Array] {
        &self.columns
    }

    pub fn column(&self, i: usize) -> &Array {
        &self.columns[i]
    }

    /// Column by name.
    pub fn column_by_name(&self, name: &str) -> Result<&Array> {
        Ok(&self.columns[self.schema.index_of(name)?])
    }

    /// Cell accessor (slow path; tests and pretty printing).
    pub fn cell(&self, row: usize, col: usize) -> Scalar {
        self.columns[col].get(row)
    }

    /// One row as scalars (slow path).
    pub fn row(&self, i: usize) -> Vec<Scalar> {
        self.columns.iter().map(|c| c.get(i)).collect()
    }

    /// Approximate heap footprint.
    pub fn nbytes(&self) -> usize {
        self.columns.iter().map(|c| c.nbytes()).sum()
    }

    // ---- structural ops (the cheap, schema-level ones live here; the
    //      relational operators live in `crate::ops`) -------------------

    /// Gather rows by index into a new table.
    pub fn take(&self, indices: &[usize]) -> Table {
        let columns = self.columns.iter().map(|c| c.take(indices)).collect();
        Table { schema: self.schema.clone(), columns, nrows: indices.len() }
    }

    /// Contiguous row range copy.
    pub fn slice(&self, start: usize, len: usize) -> Table {
        let len = len.min(self.nrows.saturating_sub(start));
        let columns = self.columns.iter().map(|c| c.slice(start, len)).collect();
        Table { schema: self.schema.clone(), columns, nrows: len }
    }

    /// First `n` rows.
    pub fn head(&self, n: usize) -> Table {
        self.slice(0, n)
    }

    /// Last `n` rows.
    pub fn tail(&self, n: usize) -> Table {
        let n = n.min(self.nrows);
        self.slice(self.nrows - n, n)
    }

    /// Keep the named columns, in the given order (relational Project).
    pub fn select_columns(&self, names: &[&str]) -> Result<Table> {
        let idx = names
            .iter()
            .map(|n| self.schema.index_of(n))
            .collect::<Result<Vec<_>>>()?;
        Ok(self.project(&idx))
    }

    /// Keep columns by index, in the given order.
    pub fn project(&self, indices: &[usize]) -> Table {
        let schema = self.schema.project(indices);
        let columns = indices.iter().map(|&i| self.columns[i].clone()).collect();
        Table { schema: Arc::new(schema), columns, nrows: self.nrows }
    }

    /// Drop the named columns.
    pub fn drop_columns(&self, names: &[&str]) -> Result<Table> {
        for n in names {
            self.schema.index_of(n)?; // error on unknown names
        }
        let keep: Vec<usize> = (0..self.num_columns())
            .filter(|&i| !names.contains(&self.schema.field(i).name.as_str()))
            .collect();
        Ok(self.project(&keep))
    }

    /// Add (or replace) a column.
    pub fn with_column(&self, name: &str, array: Array) -> Result<Table> {
        if array.len() != self.nrows {
            bail!("with_column: length mismatch ({} vs {})", array.len(), self.nrows);
        }
        let mut fields: Vec<Field> = self.schema.fields().to_vec();
        let mut columns = self.columns.clone();
        match self.schema.index_of(name) {
            Ok(i) => {
                fields[i] = Field::new(name, array.data_type());
                columns[i] = array;
            }
            Err(_) => {
                fields.push(Field::new(name, array.data_type()));
                columns.push(array);
            }
        }
        Table::new(Schema::new(fields), columns)
    }

    /// Rename one column.
    pub fn rename(&self, from: &str, to: &str) -> Result<Table> {
        let schema = self.schema.rename(from, to)?;
        Ok(Table { schema: Arc::new(schema), columns: self.columns.clone(), nrows: self.nrows })
    }

    /// Prefix every column name (Pandas `add_prefix`).
    pub fn add_prefix(&self, prefix: &str) -> Table {
        Table {
            schema: Arc::new(self.schema.add_prefix(prefix)),
            columns: self.columns.clone(),
            nrows: self.nrows,
        }
    }

    /// Vertically stack union-compatible tables (schema of the first wins).
    pub fn concat_tables(tables: &[&Table]) -> Result<Table> {
        let Some(first) = tables.first() else { bail!("concat of zero tables") };
        for t in tables {
            if !first.schema.type_compatible(&t.schema) {
                bail!("concat: incompatible schemas {} vs {}", first.schema, t.schema);
            }
        }
        let ncols = first.num_columns();
        let mut columns = Vec::with_capacity(ncols);
        for c in 0..ncols {
            let parts: Vec<&Array> = tables.iter().map(|t| &t.columns[c]).collect();
            columns.push(Array::concat(&parts));
        }
        let nrows = tables.iter().map(|t| t.nrows).sum();
        Ok(Table { schema: first.schema.clone(), columns, nrows })
    }

    /// Re-encode every `Utf8` column to [`Array::DictUtf8`] (physical
    /// only — the schema is unchanged, and logical content round-trips
    /// byte-exactly through [`crate::table::ipc::serialize`]).
    pub fn dict_encode_columns(&self) -> Table {
        let columns = self.columns.iter().map(|c| c.clone().dict_encode()).collect();
        Table { schema: self.schema.clone(), columns, nrows: self.nrows }
    }

    /// Re-encode every [`Array::DictUtf8`] column back to plain `Utf8`.
    pub fn dict_decode_columns(&self) -> Table {
        let columns = self.columns.iter().map(|c| c.clone().dict_decode()).collect();
        Table { schema: self.schema.clone(), columns, nrows: self.nrows }
    }

    /// Split into `n` contiguous chunks of near-equal size (row-partition
    /// for pleasingly-parallel dispatch; last chunks may be one row
    /// shorter).
    pub fn split(&self, n: usize) -> Vec<Table> {
        assert!(n > 0);
        let base = self.nrows / n;
        let extra = self.nrows % n;
        let mut out = Vec::with_capacity(n);
        let mut start = 0;
        for k in 0..n {
            let len = base + usize::from(k < extra);
            out.push(self.slice(start, len));
            start += len;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::table::scalar::DataType;

    fn t() -> Table {
        Table::from_columns(vec![
            ("id", Array::from_i64(vec![1, 2, 3, 4])),
            ("name", Array::from_strs(&["a", "b", "c", "d"])),
            ("score", Array::from_f64(vec![0.1, 0.2, 0.3, 0.4])),
        ])
        .unwrap()
    }

    #[test]
    fn construction_checks() {
        assert!(Table::from_columns(vec![
            ("a", Array::from_i64(vec![1])),
            ("b", Array::from_i64(vec![1, 2])),
        ])
        .is_err());
        let tbl = t();
        assert_eq!(tbl.num_rows(), 4);
        assert_eq!(tbl.num_columns(), 3);
    }

    #[test]
    fn take_and_slice() {
        let tbl = t();
        let g = tbl.take(&[2, 0]);
        assert_eq!(g.cell(0, 0), Scalar::Int64(3));
        assert_eq!(g.cell(1, 1), Scalar::Utf8("a".into()));
        let s = tbl.slice(1, 2);
        assert_eq!(s.num_rows(), 2);
        assert_eq!(s.cell(0, 0), Scalar::Int64(2));
        assert_eq!(tbl.head(2).num_rows(), 2);
        assert_eq!(tbl.tail(1).cell(0, 0), Scalar::Int64(4));
    }

    #[test]
    fn column_ops() {
        let tbl = t();
        let p = tbl.select_columns(&["score", "id"]).unwrap();
        assert_eq!(p.schema().names(), vec!["score", "id"]);
        let d = tbl.drop_columns(&["name"]).unwrap();
        assert_eq!(d.num_columns(), 2);
        assert!(tbl.drop_columns(&["nope"]).is_err());
        let w = tbl.with_column("flag", Array::from_bools(vec![true, false, true, false])).unwrap();
        assert_eq!(w.num_columns(), 4);
        let w2 = w.with_column("id", Array::from_f64(vec![0.0; 4])).unwrap();
        assert_eq!(w2.column_by_name("id").unwrap().data_type(), DataType::Float64);
        let r = tbl.rename("id", "key").unwrap();
        assert!(r.schema().contains("key"));
        let pre = tbl.add_prefix("p_");
        assert!(pre.schema().contains("p_id"));
    }

    #[test]
    fn concat_and_split() {
        let tbl = t();
        let c = Table::concat_tables(&[&tbl, &tbl]).unwrap();
        assert_eq!(c.num_rows(), 8);
        let parts = tbl.split(3);
        assert_eq!(parts.iter().map(|p| p.num_rows()).collect::<Vec<_>>(), vec![2, 1, 1]);
        let back = Table::concat_tables(&parts.iter().collect::<Vec<_>>()).unwrap();
        assert_eq!(back, tbl);
    }

    #[test]
    fn empty_table() {
        let e = Table::empty(Schema::new(vec![Field::new("x", DataType::Int64)]));
        assert_eq!(e.num_rows(), 0);
        assert_eq!(e.num_columns(), 1);
    }
}
