//! CSV reader/writer with type inference.
//!
//! Covers what the UNOMT pipeline and the examples need: header row,
//! configurable delimiter, RFC-4180 quoting (including newlines inside
//! quoted fields), null tokens (empty string, "NA", "null", "NaN"), and
//! two-pass type inference. Each non-null cell classifies to the
//! narrowest of int64 / float64 / bool / timestamp (ISO-8601, see
//! [`super::time`]) and the column type is the lattice join: int64
//! widens to float64, every other mix falls back to utf8 — mixed
//! numeric/bool columns in particular must NOT infer bool, or numeric
//! cells would silently parse as `false`.

use super::builder::TableBuilder;
use super::scalar::DataType;
use super::schema::{Field, Schema};
use super::table::Table;
use super::time::parse_timestamp_ms;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::path::Path;

/// Reader options.
#[derive(Debug, Clone)]
pub struct CsvOptions {
    pub delimiter: u8,
    pub has_header: bool,
    /// Tokens parsed as null (in addition to the empty string).
    pub null_tokens: Vec<String>,
    /// Rows sampled for type inference (whole file is still parsed).
    pub infer_rows: usize,
}

impl Default for CsvOptions {
    fn default() -> Self {
        CsvOptions {
            delimiter: b',',
            has_header: true,
            null_tokens: vec!["NA".into(), "null".into(), "NaN".into()],
            infer_rows: 1000,
        }
    }
}

/// Split one CSV record into fields, honouring double-quote quoting.
fn split_record(line: &str, delim: u8) -> Vec<String> {
    let delim = delim as char;
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut chars = line.chars().peekable();
    let mut in_quotes = false;
    while let Some(c) = chars.next() {
        if in_quotes {
            if c == '"' {
                if chars.peek() == Some(&'"') {
                    cur.push('"');
                    chars.next();
                } else {
                    in_quotes = false;
                }
            } else {
                cur.push(c);
            }
        } else if c == '"' {
            in_quotes = true;
        } else if c == delim {
            fields.push(std::mem::take(&mut cur));
        } else {
            cur.push(c);
        }
    }
    fields.push(cur);
    fields
}

fn is_null_token(s: &str, opts: &CsvOptions) -> bool {
    s.is_empty() || opts.null_tokens.iter().any(|t| t == s)
}

/// Narrowest type of one non-null cell. The classes are disjoint:
/// bool tokens and ISO-8601 dates never parse as numbers.
fn infer_cell(s: &str) -> DataType {
    if s.parse::<i64>().is_ok() {
        DataType::Int64
    } else if s.parse::<f64>().is_ok() {
        DataType::Float64
    } else if matches!(s, "true" | "false" | "True" | "False") {
        DataType::Bool
    } else if parse_timestamp_ms(s).is_some() {
        DataType::Timestamp
    } else {
        DataType::Utf8
    }
}

/// Lattice join of two cell types: the only widening is
/// int64 → float64; any other mix is utf8. Bool and Timestamp are
/// reachable only from themselves, so a column sampled as `[1, true]`
/// falls back to utf8 instead of corrupting `1` into `false`.
fn join_types(a: DataType, b: DataType) -> DataType {
    use DataType::*;
    match (a, b) {
        _ if a == b => a,
        (Int64, Float64) | (Float64, Int64) => Float64,
        _ => Utf8,
    }
}

/// Narrowest type that parses every non-null sample of a column.
fn infer_type(samples: &[&str]) -> DataType {
    let mut t = infer_cell(samples[0]);
    for s in &samples[1..] {
        if t == DataType::Utf8 {
            break;
        }
        t = join_types(t, infer_cell(s));
    }
    t
}

/// Read a CSV from any reader.
pub fn read_csv_from<R: Read>(reader: R, opts: &CsvOptions) -> Result<Table> {
    let buf = BufReader::new(reader);
    // Assemble *logical* records: while a double quote is open, the
    // record continues across physical lines (write_csv_to emits such
    // fields whenever a cell contains '\n'). Quote parity per line is
    // exact — an escaped `""` toggles twice, netting out.
    let mut lines: Vec<String> = Vec::new();
    let mut open = false;
    for line in buf.lines() {
        let line = line.context("csv: read error")?;
        let odd_quotes = line.bytes().filter(|&b| b == b'"').count() % 2 == 1;
        if open {
            let cur = lines.last_mut().expect("open quote implies a pending record");
            cur.push('\n');
            cur.push_str(&line);
            open ^= odd_quotes;
        } else {
            if line.is_empty() {
                continue;
            }
            lines.push(line);
            open = odd_quotes;
        }
    }
    if open {
        bail!("csv: unterminated quoted field at end of input");
    }
    if lines.is_empty() {
        bail!("csv: empty input");
    }

    let (header, data_lines) = if opts.has_header {
        let h = split_record(&lines[0], opts.delimiter);
        (h, &lines[1..])
    } else {
        let n = split_record(&lines[0], opts.delimiter).len();
        ((0..n).map(|i| format!("c{i}")).collect(), &lines[..])
    };
    let ncols = header.len();

    // Parse all records once.
    let mut records: Vec<Vec<String>> = Vec::with_capacity(data_lines.len());
    for (lineno, line) in data_lines.iter().enumerate() {
        let rec = split_record(line, opts.delimiter);
        if rec.len() != ncols {
            bail!(
                "csv: line {} has {} fields, expected {ncols}",
                lineno + 1 + usize::from(opts.has_header),
                rec.len()
            );
        }
        records.push(rec);
    }

    // Infer per-column types from a sample of non-null cells.
    let mut types = Vec::with_capacity(ncols);
    for c in 0..ncols {
        let samples: Vec<&str> = records
            .iter()
            .take(opts.infer_rows)
            .map(|r| r[c].as_str())
            .filter(|s| !is_null_token(s, opts))
            .collect();
        types.push(if samples.is_empty() { DataType::Utf8 } else { infer_type(&samples) });
    }

    let schema = Schema::new(
        header
            .iter()
            .zip(types.iter())
            .map(|(n, &t)| Field::new(n.clone(), t))
            .collect(),
    );
    let mut tb = TableBuilder::new(schema);
    for rec in &records {
        for (c, cell) in rec.iter().enumerate() {
            let b = tb.column_builder(c);
            if is_null_token(cell, opts) {
                b.push_null();
                continue;
            }
            match types[c] {
                DataType::Int64 => match cell.parse::<i64>() {
                    Ok(v) => b.push_i64(v),
                    Err(_) => b.push_null(), // value fell outside the inferred sample
                },
                DataType::Float64 => match cell.parse::<f64>() {
                    Ok(v) => b.push_f64(v),
                    Err(_) => b.push_null(),
                },
                DataType::Bool => b.push_bool(matches!(cell.as_str(), "true" | "True")),
                DataType::Timestamp => match parse_timestamp_ms(cell) {
                    Some(v) => b.push_ts(v),
                    None => b.push_null(),
                },
                DataType::Utf8 => b.push_str(cell),
            }
        }
    }
    Ok(tb.finish())
}

/// Read a CSV file with default options.
pub fn read_csv(path: impl AsRef<Path>) -> Result<Table> {
    read_csv_opts(path, &CsvOptions::default())
}

/// Read a CSV file.
pub fn read_csv_opts(path: impl AsRef<Path>, opts: &CsvOptions) -> Result<Table> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("csv: cannot open {}", path.as_ref().display()))?;
    read_csv_from(f, opts)
}

fn needs_quoting(s: &str, delim: u8) -> bool {
    s.bytes().any(|b| b == delim || b == b'"' || b == b'\n' || b == b'\r')
}

/// Write a table as CSV.
pub fn write_csv_to<W: Write>(table: &Table, mut w: W, opts: &CsvOptions) -> Result<()> {
    let delim = opts.delimiter as char;
    if opts.has_header {
        let names = table.schema().names();
        writeln!(w, "{}", names.join(&delim.to_string()))?;
    }
    for r in 0..table.num_rows() {
        let mut line = String::new();
        for c in 0..table.num_columns() {
            if c > 0 {
                line.push(delim);
            }
            let cell = table.cell(r, c);
            if cell.is_null() {
                continue; // null → empty field
            }
            let s = cell.to_string();
            if needs_quoting(&s, opts.delimiter) {
                line.push('"');
                line.push_str(&s.replace('"', "\"\""));
                line.push('"');
            } else {
                line.push_str(&s);
            }
        }
        writeln!(w, "{line}")?;
    }
    Ok(())
}

/// Write a table to a CSV file (Pandas `to_csv` role).
pub fn write_csv(table: &Table, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("csv: cannot create {}", path.as_ref().display()))?;
    write_csv_to(table, f, &CsvOptions::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::table::scalar::Scalar;

    #[test]
    fn infer_and_parse() {
        let data = "id,name,score,ok\n1,alpha,0.5,true\n2,beta,,false\n,gamma,2.5,true\n";
        let t = read_csv_from(data.as_bytes(), &CsvOptions::default()).unwrap();
        assert_eq!(t.num_rows(), 3);
        let s = t.schema();
        assert_eq!(s.field(0).data_type, DataType::Int64);
        assert_eq!(s.field(1).data_type, DataType::Utf8);
        assert_eq!(s.field(2).data_type, DataType::Float64);
        assert_eq!(s.field(3).data_type, DataType::Bool);
        assert_eq!(t.cell(2, 0), Scalar::Null);
        assert_eq!(t.cell(1, 2), Scalar::Null);
    }

    #[test]
    fn quoted_fields() {
        let data = "a,b\n\"x,y\",\"he said \"\"hi\"\"\"\n";
        let t = read_csv_from(data.as_bytes(), &CsvOptions::default()).unwrap();
        assert_eq!(t.cell(0, 0), Scalar::Utf8("x,y".into()));
        assert_eq!(t.cell(0, 1), Scalar::Utf8("he said \"hi\"".into()));
    }

    #[test]
    fn null_tokens() {
        let data = "x\nNA\n7\nnull\n";
        let t = read_csv_from(data.as_bytes(), &CsvOptions::default()).unwrap();
        assert_eq!(t.column(0).null_count(), 2);
        assert_eq!(t.cell(1, 0), Scalar::Int64(7));
    }

    #[test]
    fn headerless() {
        let opts = CsvOptions { has_header: false, ..Default::default() };
        let t = read_csv_from("1,2\n3,4\n".as_bytes(), &opts).unwrap();
        assert_eq!(t.schema().names(), vec!["c0", "c1"]);
        assert_eq!(t.num_rows(), 2);
    }

    #[test]
    fn ragged_rejected() {
        assert!(read_csv_from("a,b\n1\n".as_bytes(), &CsvOptions::default()).is_err());
    }

    #[test]
    fn quoted_newlines_roundtrip() {
        // Regression: write_csv_to quotes cells containing '\n', so the
        // reader must assemble logical records across physical lines.
        let t = Table::from_columns(vec![
            ("id", crate::table::array::Array::from_i64(vec![1, 2])),
            ("s", crate::table::array::Array::from_strs(&["line1\nline2", "a\n\nb,c"])),
        ])
        .unwrap();
        let mut buf = Vec::new();
        write_csv_to(&t, &mut buf, &CsvOptions::default()).unwrap();
        let rt = read_csv_from(&buf[..], &CsvOptions::default()).unwrap();
        assert_eq!(rt.num_rows(), 2);
        assert_eq!(rt.cell(0, 1), Scalar::Utf8("line1\nline2".into()));
        assert_eq!(rt.cell(1, 1), Scalar::Utf8("a\n\nb,c".into()));
        // direct parse, with an escaped quote inside the multi-line field
        let data = "a,b\n1,\"x\n\"\"y\"\"\nz\"\n2,w\n";
        let t2 = read_csv_from(data.as_bytes(), &CsvOptions::default()).unwrap();
        assert_eq!(t2.num_rows(), 2);
        assert_eq!(t2.cell(0, 1), Scalar::Utf8("x\n\"y\"\nz".into()));
        // unterminated quote fails loudly instead of mis-assembling
        assert!(read_csv_from("a\n\"oops\n".as_bytes(), &CsvOptions::default()).is_err());
    }

    #[test]
    fn mixed_numeric_bool_infers_utf8() {
        // Regression: [1, true] used to infer Bool, silently parsing the
        // cell `1` as `false`. Both sample orders must fall back to Utf8.
        let t = read_csv_from("x\n1\ntrue\n".as_bytes(), &CsvOptions::default()).unwrap();
        assert_eq!(t.schema().field(0).data_type, DataType::Utf8);
        assert_eq!(t.cell(0, 0), Scalar::Utf8("1".into()));
        assert_eq!(t.cell(1, 0), Scalar::Utf8("true".into()));
        let t = read_csv_from("x\ntrue\n1\n".as_bytes(), &CsvOptions::default()).unwrap();
        assert_eq!(t.schema().field(0).data_type, DataType::Utf8);
        assert_eq!(t.cell(1, 0), Scalar::Utf8("1".into()));
        // pure bool columns still infer Bool
        let t = read_csv_from("x\ntrue\nFalse\n".as_bytes(), &CsvOptions::default()).unwrap();
        assert_eq!(t.schema().field(0).data_type, DataType::Bool);
    }

    #[test]
    fn timestamp_inference_and_roundtrip() {
        let data = "ts,v\n2021-08-13,1\n2021-08-13T09:30:00.123Z,2\nNA,3\n";
        let t = read_csv_from(data.as_bytes(), &CsvOptions::default()).unwrap();
        assert_eq!(t.schema().field(0).data_type, DataType::Timestamp);
        assert_eq!(t.cell(1, 0), Scalar::Timestamp(1_628_847_000_123));
        assert_eq!(t.cell(2, 0), Scalar::Null);
        // write → read re-infers Timestamp (canonical format parses back)
        let mut buf = Vec::new();
        write_csv_to(&t, &mut buf, &CsvOptions::default()).unwrap();
        let rt = read_csv_from(&buf[..], &CsvOptions::default()).unwrap();
        assert_eq!(rt.schema().field(0).data_type, DataType::Timestamp);
        assert_eq!(rt.column(0), t.column(0));
        // mixed timestamp / int falls back to Utf8
        let t = read_csv_from("x\n2021-08-13\n7\n".as_bytes(), &CsvOptions::default()).unwrap();
        assert_eq!(t.schema().field(0).data_type, DataType::Utf8);
    }

    #[test]
    fn write_roundtrip() {
        let t = Table::from_columns(vec![
            ("id", crate::table::array::Array::from_opt_i64(vec![Some(1), None])),
            ("s", crate::table::array::Array::from_strs(&["a,b", "plain"])),
        ])
        .unwrap();
        let mut buf = Vec::new();
        write_csv_to(&t, &mut buf, &CsvOptions::default()).unwrap();
        let rt = read_csv_from(&buf[..], &CsvOptions::default()).unwrap();
        assert_eq!(rt.cell(0, 1), Scalar::Utf8("a,b".into()));
        assert_eq!(rt.cell(1, 0), Scalar::Null);
    }
}
