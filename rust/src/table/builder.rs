//! Incremental builders for arrays and tables (CSV reader, data
//! generators and shuffle receive path all append row-at-a-time or
//! cell-at-a-time).

use super::array::{Array, Utf8Data};
use super::bitmap::Bitmap;
use super::scalar::{DataType, Scalar};
use super::schema::{Schema, SchemaRef};
use super::table::Table;
use anyhow::{bail, Result};
use std::sync::Arc;

/// Builder for a single column.
#[derive(Debug)]
pub enum ArrayBuilder {
    Int64(Vec<i64>, Bitmap, bool),
    Float64(Vec<f64>, Bitmap, bool),
    Utf8(Utf8Data, Bitmap, bool),
    Bool(Vec<bool>, Bitmap, bool),
    Timestamp(Vec<i64>, Bitmap, bool),
}

impl ArrayBuilder {
    pub fn new(dt: DataType) -> ArrayBuilder {
        Self::with_capacity(dt, 0)
    }

    pub fn with_capacity(dt: DataType, cap: usize) -> ArrayBuilder {
        match dt {
            DataType::Int64 => ArrayBuilder::Int64(Vec::with_capacity(cap), Bitmap::new_null(0), false),
            DataType::Float64 => {
                ArrayBuilder::Float64(Vec::with_capacity(cap), Bitmap::new_null(0), false)
            }
            DataType::Utf8 => ArrayBuilder::Utf8(Utf8Data::empty(), Bitmap::new_null(0), false),
            DataType::Bool => ArrayBuilder::Bool(Vec::with_capacity(cap), Bitmap::new_null(0), false),
            DataType::Timestamp => {
                ArrayBuilder::Timestamp(Vec::with_capacity(cap), Bitmap::new_null(0), false)
            }
        }
    }

    pub fn data_type(&self) -> DataType {
        match self {
            ArrayBuilder::Int64(..) => DataType::Int64,
            ArrayBuilder::Float64(..) => DataType::Float64,
            ArrayBuilder::Utf8(..) => DataType::Utf8,
            ArrayBuilder::Bool(..) => DataType::Bool,
            ArrayBuilder::Timestamp(..) => DataType::Timestamp,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            ArrayBuilder::Int64(v, ..) => v.len(),
            ArrayBuilder::Float64(v, ..) => v.len(),
            ArrayBuilder::Utf8(d, ..) => d.len(),
            ArrayBuilder::Bool(v, ..) => v.len(),
            ArrayBuilder::Timestamp(v, ..) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn push_i64(&mut self, v: i64) {
        match self {
            ArrayBuilder::Int64(vals, bm, _) => {
                vals.push(v);
                bm.push(true);
            }
            _ => panic!("push_i64 on {:?} builder", self.data_type()),
        }
    }

    pub fn push_f64(&mut self, v: f64) {
        match self {
            ArrayBuilder::Float64(vals, bm, _) => {
                vals.push(v);
                bm.push(true);
            }
            _ => panic!("push_f64 on {:?} builder", self.data_type()),
        }
    }

    pub fn push_str(&mut self, v: &str) {
        match self {
            ArrayBuilder::Utf8(data, bm, _) => {
                data.push(v);
                bm.push(true);
            }
            _ => panic!("push_str on {:?} builder", self.data_type()),
        }
    }

    pub fn push_bool(&mut self, v: bool) {
        match self {
            ArrayBuilder::Bool(vals, bm, _) => {
                vals.push(v);
                bm.push(true);
            }
            _ => panic!("push_bool on {:?} builder", self.data_type()),
        }
    }

    pub fn push_ts(&mut self, v: i64) {
        match self {
            ArrayBuilder::Timestamp(vals, bm, _) => {
                vals.push(v);
                bm.push(true);
            }
            _ => panic!("push_ts on {:?} builder", self.data_type()),
        }
    }

    pub fn push_null(&mut self) {
        match self {
            ArrayBuilder::Int64(vals, bm, n) => {
                vals.push(0);
                bm.push(false);
                *n = true;
            }
            ArrayBuilder::Float64(vals, bm, n) => {
                vals.push(0.0);
                bm.push(false);
                *n = true;
            }
            ArrayBuilder::Utf8(data, bm, n) => {
                data.push("");
                bm.push(false);
                *n = true;
            }
            ArrayBuilder::Bool(vals, bm, n) => {
                vals.push(false);
                bm.push(false);
                *n = true;
            }
            ArrayBuilder::Timestamp(vals, bm, n) => {
                vals.push(0);
                bm.push(false);
                *n = true;
            }
        }
    }

    /// Append a scalar; must match the builder type or be null.
    pub fn push_scalar(&mut self, s: &Scalar) -> Result<()> {
        match (self, s) {
            (b, Scalar::Null) => b.push_null(),
            (b @ ArrayBuilder::Int64(..), Scalar::Int64(v)) => b.push_i64(*v),
            (b @ ArrayBuilder::Float64(..), Scalar::Float64(v)) => b.push_f64(*v),
            // widen int into float columns (CSV inference may settle on
            // float after seeing ints first)
            (b @ ArrayBuilder::Float64(..), Scalar::Int64(v)) => b.push_f64(*v as f64),
            (b @ ArrayBuilder::Utf8(..), Scalar::Utf8(v)) => b.push_str(v),
            (b @ ArrayBuilder::Bool(..), Scalar::Bool(v)) => b.push_bool(*v),
            (b @ ArrayBuilder::Timestamp(..), Scalar::Timestamp(v)) => b.push_ts(*v),
            (b, s) => bail!("type mismatch: {} builder, {:?} scalar", b.data_type(), s),
        }
        Ok(())
    }

    /// Append cell `i` of `src` (shuffle receive path).
    pub fn push_from(&mut self, src: &Array, i: usize) {
        if src.is_null(i) {
            self.push_null();
            return;
        }
        match (self, src) {
            (b @ ArrayBuilder::Int64(..), Array::Int64(v, _)) => b.push_i64(v[i]),
            (b @ ArrayBuilder::Float64(..), Array::Float64(v, _)) => b.push_f64(v[i]),
            (b @ ArrayBuilder::Utf8(..), Array::Utf8(d, _)) => b.push_str(d.value(i)),
            // Dictionary-encoded sources feed plain string builders:
            // builders are row-at-a-time slow paths, so no code space to
            // preserve here.
            (b @ ArrayBuilder::Utf8(..), Array::DictUtf8(d, _)) => b.push_str(d.value(i)),
            (b @ ArrayBuilder::Bool(..), Array::Bool(v, _)) => b.push_bool(v[i]),
            (b @ ArrayBuilder::Timestamp(..), Array::Timestamp(v, _)) => b.push_ts(v[i]),
            (b, s) => panic!("push_from type mismatch: {} vs {}", b.data_type(), s.data_type()),
        }
    }

    pub fn finish(self) -> Array {
        match self {
            ArrayBuilder::Int64(v, bm, any_null) => {
                Array::Int64(v, if any_null { Some(bm) } else { None })
            }
            ArrayBuilder::Float64(v, bm, any_null) => {
                Array::Float64(v, if any_null { Some(bm) } else { None })
            }
            ArrayBuilder::Utf8(d, bm, any_null) => {
                Array::Utf8(d, if any_null { Some(bm) } else { None })
            }
            ArrayBuilder::Bool(v, bm, any_null) => {
                Array::Bool(v, if any_null { Some(bm) } else { None })
            }
            ArrayBuilder::Timestamp(v, bm, any_null) => {
                Array::Timestamp(v, if any_null { Some(bm) } else { None })
            }
        }
    }
}

/// Builder for a whole table (one `ArrayBuilder` per field).
#[derive(Debug)]
pub struct TableBuilder {
    schema: SchemaRef,
    builders: Vec<ArrayBuilder>,
}

impl TableBuilder {
    pub fn new(schema: Schema) -> TableBuilder {
        Self::shared(Arc::new(schema), 0)
    }

    pub fn shared(schema: SchemaRef, cap: usize) -> TableBuilder {
        let builders = schema
            .fields()
            .iter()
            .map(|f| ArrayBuilder::with_capacity(f.data_type, cap))
            .collect();
        TableBuilder { schema, builders }
    }

    pub fn schema(&self) -> &SchemaRef {
        &self.schema
    }

    pub fn num_rows(&self) -> usize {
        self.builders.first().map_or(0, |b| b.len())
    }

    pub fn column_builder(&mut self, i: usize) -> &mut ArrayBuilder {
        &mut self.builders[i]
    }

    /// Append a full row of scalars.
    pub fn push_row(&mut self, row: &[Scalar]) -> Result<()> {
        if row.len() != self.builders.len() {
            bail!("row has {} cells, schema has {}", row.len(), self.builders.len());
        }
        for (b, s) in self.builders.iter_mut().zip(row.iter()) {
            b.push_scalar(s)?;
        }
        Ok(())
    }

    /// Append row `i` of `src` (schemas must be type-compatible).
    pub fn push_table_row(&mut self, src: &Table, i: usize) {
        for (b, c) in self.builders.iter_mut().zip(src.columns().iter()) {
            b.push_from(c, i);
        }
    }

    pub fn finish(self) -> Table {
        let columns: Vec<Array> = self.builders.into_iter().map(|b| b.finish()).collect();
        Table::new_shared(self.schema, columns).expect("builder produced consistent table")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::table::schema::Field;

    #[test]
    fn build_with_nulls() {
        let mut b = ArrayBuilder::new(DataType::Int64);
        b.push_i64(1);
        b.push_null();
        b.push_i64(3);
        let a = b.finish();
        assert_eq!(a.len(), 3);
        assert_eq!(a.null_count(), 1);
        assert_eq!(a.get(2), Scalar::Int64(3));
    }

    #[test]
    fn no_nulls_no_bitmap() {
        let mut b = ArrayBuilder::new(DataType::Utf8);
        b.push_str("x");
        b.push_str("y");
        let a = b.finish();
        assert!(a.validity().is_none());
    }

    #[test]
    fn int_widens_into_float_builder() {
        let mut b = ArrayBuilder::new(DataType::Float64);
        b.push_scalar(&Scalar::Int64(2)).unwrap();
        assert_eq!(b.finish().get(0), Scalar::Float64(2.0));
    }

    #[test]
    fn table_builder_roundtrip() {
        let schema = Schema::new(vec![
            Field::new("id", DataType::Int64),
            Field::new("tag", DataType::Utf8),
        ]);
        let mut tb = TableBuilder::new(schema);
        tb.push_row(&[Scalar::Int64(1), Scalar::Utf8("a".into())]).unwrap();
        tb.push_row(&[Scalar::Null, Scalar::Utf8("b".into())]).unwrap();
        let t = tb.finish();
        assert_eq!(t.num_rows(), 2);
        assert_eq!(t.cell(1, 0), Scalar::Null);

        // push_table_row copies across
        let mut tb2 = TableBuilder::shared(t.schema().clone(), 2);
        tb2.push_table_row(&t, 1);
        let t2 = tb2.finish();
        assert_eq!(t2.cell(0, 1), Scalar::Utf8("b".into()));
    }

    #[test]
    fn row_arity_checked() {
        let mut tb = TableBuilder::new(Schema::new(vec![Field::new("x", DataType::Int64)]));
        assert!(tb.push_row(&[Scalar::Int64(1), Scalar::Int64(2)]).is_err());
    }
}
