//! Table schemas: ordered, named, typed fields.

use super::scalar::DataType;
use anyhow::{bail, Result};
use std::fmt;
use std::sync::Arc;

/// A named, typed column descriptor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Field {
    pub name: String,
    pub data_type: DataType,
    pub nullable: bool,
}

impl Field {
    pub fn new(name: impl Into<String>, data_type: DataType) -> Self {
        Field { name: name.into(), data_type, nullable: true }
    }

    pub fn not_null(name: impl Into<String>, data_type: DataType) -> Self {
        Field { name: name.into(), data_type, nullable: false }
    }
}

/// An ordered collection of fields. Shared via `Arc` between tables that
/// have the same shape (e.g. partitions of one distributed table).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Schema {
    fields: Vec<Field>,
}

pub type SchemaRef = Arc<Schema>;

impl Schema {
    pub fn new(fields: Vec<Field>) -> Self {
        Schema { fields }
    }

    pub fn empty() -> Self {
        Schema { fields: Vec::new() }
    }

    pub fn fields(&self) -> &[Field] {
        &self.fields
    }

    pub fn len(&self) -> usize {
        self.fields.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fields.is_empty()
    }

    pub fn field(&self, i: usize) -> &Field {
        &self.fields[i]
    }

    /// Index of the column with the given name.
    pub fn index_of(&self, name: &str) -> Result<usize> {
        match self.fields.iter().position(|f| f.name == name) {
            Some(i) => Ok(i),
            None => bail!(
                "column {name:?} not found (have: {:?})",
                self.fields.iter().map(|f| &f.name).collect::<Vec<_>>()
            ),
        }
    }

    pub fn contains(&self, name: &str) -> bool {
        self.fields.iter().any(|f| f.name == name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.fields.iter().map(|f| f.name.as_str()).collect()
    }

    /// Sub-schema by column indices.
    pub fn project(&self, indices: &[usize]) -> Schema {
        Schema { fields: indices.iter().map(|&i| self.fields[i].clone()).collect() }
    }

    /// New schema with a prefix prepended to every column name
    /// (Pandas' `add_prefix`, used by the UNOMT pipeline).
    pub fn add_prefix(&self, prefix: &str) -> Schema {
        Schema {
            fields: self
                .fields
                .iter()
                .map(|f| Field { name: format!("{prefix}{}", f.name), ..f.clone() })
                .collect(),
        }
    }

    /// New schema with one column renamed.
    pub fn rename(&self, from: &str, to: &str) -> Result<Schema> {
        let i = self.index_of(from)?;
        let mut fields = self.fields.clone();
        fields[i].name = to.to_string();
        Ok(Schema { fields })
    }

    /// Two schemas are concat-compatible when types match positionally
    /// (names may differ — vertical concat keeps the first schema's).
    pub fn type_compatible(&self, other: &Schema) -> bool {
        self.fields.len() == other.fields.len()
            && self
                .fields
                .iter()
                .zip(other.fields.iter())
                .all(|(a, b)| a.data_type == b.data_type)
    }

    /// Strict union compatibility for the relational set operators:
    /// names AND types must match positionally, so differently-shaped
    /// tables error instead of silently zipping columns by position.
    pub fn union_compatible(&self, other: &Schema) -> bool {
        self.fields.len() == other.fields.len()
            && self
                .fields
                .iter()
                .zip(other.fields.iter())
                .all(|(a, b)| a.name == b.name && a.data_type == b.data_type)
    }
}

impl fmt::Display for Schema {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[")?;
        for (i, fld) in self.fields.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{}: {}", fld.name, fld.data_type)?;
        }
        write!(f, "]")
    }
}

impl FromIterator<Field> for Schema {
    fn from_iter<T: IntoIterator<Item = Field>>(iter: T) -> Self {
        Schema { fields: iter.into_iter().collect() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s() -> Schema {
        Schema::new(vec![
            Field::new("id", DataType::Int64),
            Field::new("name", DataType::Utf8),
            Field::new("score", DataType::Float64),
        ])
    }

    #[test]
    fn lookup() {
        let sc = s();
        assert_eq!(sc.index_of("name").unwrap(), 1);
        assert!(sc.index_of("missing").is_err());
        assert!(sc.contains("score"));
    }

    #[test]
    fn project_and_prefix() {
        let sc = s().project(&[2, 0]);
        assert_eq!(sc.names(), vec!["score", "id"]);
        let p = sc.add_prefix("x_");
        assert_eq!(p.names(), vec!["x_score", "x_id"]);
    }

    #[test]
    fn rename_and_compat() {
        let sc = s().rename("id", "key").unwrap();
        assert_eq!(sc.names()[0], "key");
        assert!(sc.type_compatible(&s()));
        assert!(!sc.project(&[0]).type_compatible(&s()));
    }

    #[test]
    fn union_compat_requires_names_and_types() {
        let sc = s();
        assert!(sc.union_compatible(&s()));
        let renamed = s().rename("id", "key").unwrap();
        assert!(renamed.type_compatible(&sc), "types still line up");
        assert!(!renamed.union_compatible(&sc), "but names differ");
        let retyped = Schema::new(vec![
            Field::new("id", DataType::Utf8),
            Field::new("name", DataType::Utf8),
            Field::new("score", DataType::Float64),
        ]);
        assert!(!retyped.union_compatible(&sc));
        assert!(!sc.project(&[0, 1]).union_compatible(&sc));
    }
}
