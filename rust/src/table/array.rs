//! Columnar arrays: the unit of vectorised execution.
//!
//! Four physical layouts (matching [`DataType`]):
//! * `Int64`  — `Vec<i64>` values + optional validity bitmap
//! * `Float64`— `Vec<f64>` values + optional validity bitmap
//! * `Utf8`   — Arrow-style `offsets: Vec<u32>` + `bytes: Vec<u8>` + bitmap
//! * `Bool`   — `Vec<bool>` values + optional validity bitmap
//!
//! Null slots hold a zero/empty payload; consumers must consult the
//! bitmap. An absent bitmap means "all valid".

use super::bitmap::Bitmap;
use super::scalar::{DataType, Scalar};

/// UTF-8 column payload: `value(i) = bytes[offsets[i]..offsets[i+1]]`.
#[derive(Debug, Clone, PartialEq)]
pub struct Utf8Data {
    pub offsets: Vec<u32>,
    pub bytes: Vec<u8>,
}

impl Utf8Data {
    pub fn empty() -> Self {
        Utf8Data { offsets: vec![0], bytes: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[inline]
    pub fn value(&self, i: usize) -> &str {
        let lo = self.offsets[i] as usize;
        let hi = self.offsets[i + 1] as usize;
        // Safety: builders only append valid UTF-8.
        unsafe { std::str::from_utf8_unchecked(&self.bytes[lo..hi]) }
    }

    pub fn push(&mut self, s: &str) {
        self.bytes.extend_from_slice(s.as_bytes());
        self.offsets.push(self.bytes.len() as u32);
    }

    pub fn from_strs<S: AsRef<str>>(vals: &[S]) -> Self {
        let total: usize = vals.iter().map(|s| s.as_ref().len()).sum();
        let mut d = Utf8Data { offsets: Vec::with_capacity(vals.len() + 1), bytes: Vec::with_capacity(total) };
        d.offsets.push(0);
        for s in vals {
            d.push(s.as_ref());
        }
        d
    }
}

/// A column of data. Cheap to clone? No — clones copy buffers; operators
/// move or borrow. Wrap in `Arc` at the [`Table`](super::table::Table)
/// level when sharing is needed.
#[derive(Debug, Clone, PartialEq)]
pub enum Array {
    Int64(Vec<i64>, Option<Bitmap>),
    Float64(Vec<f64>, Option<Bitmap>),
    Utf8(Utf8Data, Option<Bitmap>),
    Bool(Vec<bool>, Option<Bitmap>),
}

impl Array {
    // ---- constructors -------------------------------------------------

    pub fn from_i64(v: Vec<i64>) -> Array {
        Array::Int64(v, None)
    }

    pub fn from_f64(v: Vec<f64>) -> Array {
        Array::Float64(v, None)
    }

    pub fn from_strs<S: AsRef<str>>(v: &[S]) -> Array {
        Array::Utf8(Utf8Data::from_strs(v), None)
    }

    pub fn from_bools(v: Vec<bool>) -> Array {
        Array::Bool(v, None)
    }

    /// From options; `None` entries become nulls.
    pub fn from_opt_i64(v: Vec<Option<i64>>) -> Array {
        let mut vals = Vec::with_capacity(v.len());
        let mut bm = Bitmap::new_null(v.len());
        let mut any_null = false;
        for (i, o) in v.into_iter().enumerate() {
            match o {
                Some(x) => {
                    vals.push(x);
                    bm.set(i, true);
                }
                None => {
                    vals.push(0);
                    any_null = true;
                }
            }
        }
        Array::Int64(vals, if any_null { Some(bm) } else { None })
    }

    pub fn from_opt_f64(v: Vec<Option<f64>>) -> Array {
        let mut vals = Vec::with_capacity(v.len());
        let mut bm = Bitmap::new_null(v.len());
        let mut any_null = false;
        for (i, o) in v.into_iter().enumerate() {
            match o {
                Some(x) => {
                    vals.push(x);
                    bm.set(i, true);
                }
                None => {
                    vals.push(0.0);
                    any_null = true;
                }
            }
        }
        Array::Float64(vals, if any_null { Some(bm) } else { None })
    }

    pub fn from_opt_strs(v: Vec<Option<&str>>) -> Array {
        let mut data = Utf8Data::empty();
        let mut bm = Bitmap::new_null(v.len());
        let mut any_null = false;
        for (i, o) in v.into_iter().enumerate() {
            match o {
                Some(s) => {
                    data.push(s);
                    bm.set(i, true);
                }
                None => {
                    data.push("");
                    any_null = true;
                }
            }
        }
        Array::Utf8(data, if any_null { Some(bm) } else { None })
    }

    /// An empty array of the given type.
    pub fn empty(dt: DataType) -> Array {
        match dt {
            DataType::Int64 => Array::Int64(Vec::new(), None),
            DataType::Float64 => Array::Float64(Vec::new(), None),
            DataType::Utf8 => Array::Utf8(Utf8Data::empty(), None),
            DataType::Bool => Array::Bool(Vec::new(), None),
        }
    }

    // ---- inspectors ----------------------------------------------------

    pub fn data_type(&self) -> DataType {
        match self {
            Array::Int64(..) => DataType::Int64,
            Array::Float64(..) => DataType::Float64,
            Array::Utf8(..) => DataType::Utf8,
            Array::Bool(..) => DataType::Bool,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Array::Int64(v, _) => v.len(),
            Array::Float64(v, _) => v.len(),
            Array::Utf8(d, _) => d.len(),
            Array::Bool(v, _) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn validity(&self) -> Option<&Bitmap> {
        match self {
            Array::Int64(_, b) | Array::Float64(_, b) | Array::Utf8(_, b) | Array::Bool(_, b) => {
                b.as_ref()
            }
        }
    }

    #[inline]
    pub fn is_valid(&self, i: usize) -> bool {
        match self.validity() {
            None => true,
            Some(b) => b.get(i),
        }
    }

    #[inline]
    pub fn is_null(&self, i: usize) -> bool {
        !self.is_valid(i)
    }

    pub fn null_count(&self) -> usize {
        self.validity().map_or(0, |b| b.count_null())
    }

    /// Cell accessor (slow path).
    pub fn get(&self, i: usize) -> Scalar {
        if self.is_null(i) {
            return Scalar::Null;
        }
        match self {
            Array::Int64(v, _) => Scalar::Int64(v[i]),
            Array::Float64(v, _) => Scalar::Float64(v[i]),
            Array::Utf8(d, _) => Scalar::Utf8(d.value(i).to_string()),
            Array::Bool(v, _) => Scalar::Bool(v[i]),
        }
    }

    // ---- typed views ---------------------------------------------------

    pub fn i64_values(&self) -> Option<&[i64]> {
        match self {
            Array::Int64(v, _) => Some(v),
            _ => None,
        }
    }

    pub fn f64_values(&self) -> Option<&[f64]> {
        match self {
            Array::Float64(v, _) => Some(v),
            _ => None,
        }
    }

    pub fn utf8_data(&self) -> Option<&Utf8Data> {
        match self {
            Array::Utf8(d, _) => Some(d),
            _ => None,
        }
    }

    pub fn bool_values(&self) -> Option<&[bool]> {
        match self {
            Array::Bool(v, _) => Some(v),
            _ => None,
        }
    }

    /// Numeric view of cell `i`, widening ints; None when null or non-numeric.
    #[inline]
    pub fn f64_at(&self, i: usize) -> Option<f64> {
        if self.is_null(i) {
            return None;
        }
        match self {
            Array::Int64(v, _) => Some(v[i] as f64),
            Array::Float64(v, _) => Some(v[i]),
            _ => None,
        }
    }

    // ---- kernels --------------------------------------------------------

    /// Gather rows by index: `out[k] = self[indices[k]]`.
    ///
    /// The workhorse of select / sort / join materialisation — single
    /// pass, pre-sized output buffers.
    pub fn take(&self, indices: &[usize]) -> Array {
        let validity = self.validity().map(|b| b.take(indices));
        match self {
            Array::Int64(v, _) => {
                let out: Vec<i64> = indices.iter().map(|&i| v[i]).collect();
                Array::Int64(out, validity)
            }
            Array::Float64(v, _) => {
                let out: Vec<f64> = indices.iter().map(|&i| v[i]).collect();
                Array::Float64(out, validity)
            }
            Array::Bool(v, _) => {
                let out: Vec<bool> = indices.iter().map(|&i| v[i]).collect();
                Array::Bool(out, validity)
            }
            Array::Utf8(d, _) => {
                let total: usize = indices
                    .iter()
                    .map(|&i| (d.offsets[i + 1] - d.offsets[i]) as usize)
                    .sum();
                let mut out = Utf8Data {
                    offsets: Vec::with_capacity(indices.len() + 1),
                    bytes: Vec::with_capacity(total),
                };
                out.offsets.push(0);
                for &i in indices {
                    let lo = d.offsets[i] as usize;
                    let hi = d.offsets[i + 1] as usize;
                    out.bytes.extend_from_slice(&d.bytes[lo..hi]);
                    out.offsets.push(out.bytes.len() as u32);
                }
                Array::Utf8(out, validity)
            }
        }
    }

    /// Gather with optional indices: `None` produces a null slot (outer
    /// join materialisation).
    pub fn take_opt(&self, indices: &[Option<usize>]) -> Array {
        use super::builder::ArrayBuilder;
        let mut b = ArrayBuilder::with_capacity(self.data_type(), indices.len());
        for &i in indices {
            match i {
                Some(i) => b.push_from(self, i),
                None => b.push_null(),
            }
        }
        b.finish()
    }

    /// Contiguous slice copy `[start, start+len)`.
    pub fn slice(&self, start: usize, len: usize) -> Array {
        let idx: Vec<usize> = (start..start + len).collect();
        self.take(&idx)
    }

    /// Concatenate many arrays of the same type.
    pub fn concat(arrays: &[&Array]) -> Array {
        assert!(!arrays.is_empty(), "concat of zero arrays");
        let dt = arrays[0].data_type();
        assert!(
            arrays.iter().all(|a| a.data_type() == dt),
            "concat type mismatch"
        );
        let total: usize = arrays.iter().map(|a| a.len()).sum();
        let any_null = arrays.iter().any(|a| a.null_count() > 0);
        let validity = if any_null {
            let mut bm = Bitmap::new_null(total);
            let mut off = 0;
            for a in arrays {
                for i in 0..a.len() {
                    if a.is_valid(i) {
                        bm.set(off + i, true);
                    }
                }
                off += a.len();
            }
            Some(bm)
        } else {
            None
        };
        match dt {
            DataType::Int64 => {
                let mut out = Vec::with_capacity(total);
                for a in arrays {
                    out.extend_from_slice(a.i64_values().unwrap());
                }
                Array::Int64(out, validity)
            }
            DataType::Float64 => {
                let mut out = Vec::with_capacity(total);
                for a in arrays {
                    out.extend_from_slice(a.f64_values().unwrap());
                }
                Array::Float64(out, validity)
            }
            DataType::Bool => {
                let mut out = Vec::with_capacity(total);
                for a in arrays {
                    out.extend_from_slice(a.bool_values().unwrap());
                }
                Array::Bool(out, validity)
            }
            DataType::Utf8 => {
                let bytes_total: usize = arrays.iter().map(|a| a.utf8_data().unwrap().bytes.len()).sum();
                let mut out = Utf8Data {
                    offsets: Vec::with_capacity(total + 1),
                    bytes: Vec::with_capacity(bytes_total),
                };
                out.offsets.push(0);
                for a in arrays {
                    let d = a.utf8_data().unwrap();
                    let base = out.bytes.len() as u32;
                    out.bytes.extend_from_slice(&d.bytes);
                    out.offsets.extend(d.offsets[1..].iter().map(|o| o + base));
                }
                Array::Utf8(out, validity)
            }
        }
    }

    /// Drop the bitmap if it is all-valid (normalisation after filters).
    pub fn normalize_validity(self) -> Array {
        fn norm(b: Option<Bitmap>) -> Option<Bitmap> {
            b.filter(|bm| !bm.all_valid())
        }
        match self {
            Array::Int64(v, b) => Array::Int64(v, norm(b)),
            Array::Float64(v, b) => Array::Float64(v, norm(b)),
            Array::Utf8(d, b) => Array::Utf8(d, norm(b)),
            Array::Bool(v, b) => Array::Bool(v, norm(b)),
        }
    }

    /// Approximate heap footprint in bytes (used by the comm cost model
    /// and the pipeline's backpressure accounting).
    pub fn nbytes(&self) -> usize {
        let bm = self.validity().map_or(0, |b| b.raw().len());
        bm + match self {
            Array::Int64(v, _) => v.len() * 8,
            Array::Float64(v, _) => v.len() * 8,
            Array::Bool(v, _) => v.len(),
            Array::Utf8(d, _) => d.bytes.len() + d.offsets.len() * 4,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_get() {
        let a = Array::from_opt_i64(vec![Some(1), None, Some(3)]);
        assert_eq!(a.len(), 3);
        assert_eq!(a.null_count(), 1);
        assert_eq!(a.get(0), Scalar::Int64(1));
        assert_eq!(a.get(1), Scalar::Null);
        assert_eq!(a.f64_at(2), Some(3.0));
        assert_eq!(a.f64_at(1), None);
    }

    #[test]
    fn utf8_layout() {
        let a = Array::from_strs(&["ab", "", "xyz"]);
        let d = a.utf8_data().unwrap();
        assert_eq!(d.value(0), "ab");
        assert_eq!(d.value(1), "");
        assert_eq!(d.value(2), "xyz");
        assert_eq!(a.nbytes(), 5 + 4 * 4);
    }

    #[test]
    fn take_gathers_values_and_validity() {
        let a = Array::from_opt_strs(vec![Some("a"), None, Some("c"), Some("d")]);
        let t = a.take(&[3, 1, 0]);
        assert_eq!(t.get(0), Scalar::Utf8("d".into()));
        assert_eq!(t.get(1), Scalar::Null);
        assert_eq!(t.get(2), Scalar::Utf8("a".into()));
    }

    #[test]
    fn concat_mixed_validity() {
        let a = Array::from_i64(vec![1, 2]);
        let b = Array::from_opt_i64(vec![None, Some(4)]);
        let c = Array::concat(&[&a, &b]);
        assert_eq!(c.len(), 4);
        assert_eq!(c.null_count(), 1);
        assert_eq!(c.get(3), Scalar::Int64(4));
        assert_eq!(c.get(2), Scalar::Null);
    }

    #[test]
    fn concat_utf8_offsets_rebased() {
        let a = Array::from_strs(&["aa", "b"]);
        let b = Array::from_strs(&["ccc"]);
        let c = Array::concat(&[&a, &b]);
        assert_eq!(c.get(2), Scalar::Utf8("ccc".into()));
    }

    #[test]
    fn slice_copies_range() {
        let a = Array::from_f64(vec![0.0, 1.0, 2.0, 3.0]);
        let s = a.slice(1, 2);
        assert_eq!(s.f64_values().unwrap(), &[1.0, 2.0]);
    }

    #[test]
    fn normalize_drops_full_bitmap() {
        let mut bm = Bitmap::new_valid(2);
        bm.set(0, true);
        let a = Array::Int64(vec![1, 2], Some(bm)).normalize_validity();
        assert!(a.validity().is_none());
    }
}
