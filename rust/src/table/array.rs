//! Columnar arrays: the unit of vectorised execution.
//!
//! Six physical layouts over five logical [`DataType`]s:
//! * `Int64`   — `Vec<i64>` values + optional validity bitmap
//! * `Float64` — `Vec<f64>` values + optional validity bitmap
//! * `Utf8`    — Arrow-style `offsets: Vec<u32>` + `bytes: Vec<u8>` + bitmap
//! * `DictUtf8`— dictionary-encoded strings: `codes: Vec<u32>` into a
//!   `dict: Vec<String>` of unique entries + bitmap. A *physical*
//!   encoding of logical `Utf8`: [`Array::data_type`] reports
//!   [`DataType::Utf8`], so schemas, joins and the IPC header never see
//!   it. Hot kernels (row hash, group-by/unique probes, shuffle wire)
//!   stay in u32 code space instead of re-touching string bytes.
//! * `Bool`    — `Vec<bool>` values + optional validity bitmap
//! * `Timestamp` — `Vec<i64>` milliseconds since the Unix epoch (UTC)
//!   + optional validity bitmap; same physical shape as `Int64` but a
//!   distinct logical type (sorts and hashes like an `i64`, displays
//!   and casts as ISO-8601 — see [`super::time`])
//!
//! Null slots hold a zero/empty payload (code 0 for `DictUtf8`);
//! consumers must consult the bitmap. An absent bitmap means "all
//! valid". Note `PartialEq` on `Array` is *physical*: a `DictUtf8`
//! array never equals a plain `Utf8` array even when their logical
//! contents match — compare via [`crate::table::ipc::serialize`]
//! (which canonicalises encodings) when logical equality is meant.

use super::bitmap::Bitmap;
use super::scalar::{DataType, Scalar};
use std::collections::HashMap;

/// Dictionary-encoded UTF-8 column payload: `value(i) = dict[codes[i]]`.
///
/// Invariants maintained by the constructors and kernels here:
/// * `dict` entries are unique, in first-occurrence order;
/// * every code of a *valid* row indexes into `dict`;
/// * null rows carry code 0 (and a cleared validity bit — when `dict`
///   is empty because all rows are null, [`DictUtf8Data::value`]
///   returns `""` rather than indexing out of bounds).
#[derive(Debug, Clone, PartialEq)]
pub struct DictUtf8Data {
    /// Per-row index into `dict`.
    pub codes: Vec<u32>,
    /// Unique entries, first-occurrence order.
    pub dict: Vec<String>,
}

impl DictUtf8Data {
    /// Number of rows (not dictionary entries).
    pub fn len(&self) -> usize {
        self.codes.len()
    }

    /// True when the column has no rows.
    pub fn is_empty(&self) -> bool {
        self.codes.is_empty()
    }

    /// Row accessor. Null rows (code 0) yield whatever entry 0 holds —
    /// callers consult the validity bitmap first, exactly as with
    /// [`Utf8Data`]'s empty null payloads.
    #[inline]
    pub fn value(&self, i: usize) -> &str {
        self.dict.get(self.codes[i] as usize).map_or("", |s| s.as_str())
    }

    /// Build from plain offsets+bytes, interning each distinct valid
    /// value once. Null rows (per `validity`) get code 0 and are never
    /// interned, so an all-null column has an empty dictionary.
    pub fn encode(plain: &Utf8Data, validity: Option<&Bitmap>) -> DictUtf8Data {
        let n = plain.len();
        let mut codes = Vec::with_capacity(n);
        let mut dict: Vec<String> = Vec::new();
        let mut seen: HashMap<&str, u32> = HashMap::new();
        // Two-phase: intern borrowed &str first, copy to owned after,
        // so each distinct value is allocated exactly once.
        let mut order: Vec<&str> = Vec::new();
        for i in 0..n {
            if validity.is_some_and(|b| !b.get(i)) {
                codes.push(0);
                continue;
            }
            let v = plain.value(i);
            let code = *seen.entry(v).or_insert_with(|| {
                order.push(v);
                (order.len() - 1) as u32
            });
            codes.push(code);
        }
        dict.extend(order.iter().map(|s| s.to_string()));
        DictUtf8Data { codes, dict }
    }

    /// Expand back to plain offsets+bytes. Null rows decode to the
    /// empty payload (the builder convention), regardless of what entry
    /// 0 holds.
    pub fn decode(&self, validity: Option<&Bitmap>) -> Utf8Data {
        let mut total = 0usize;
        for (i, &c) in self.codes.iter().enumerate() {
            if validity.is_none_or(|b| b.get(i)) {
                total += self.dict[c as usize].len();
            }
        }
        let mut out = Utf8Data {
            offsets: Vec::with_capacity(self.codes.len() + 1),
            bytes: Vec::with_capacity(total),
        };
        out.offsets.push(0);
        for (i, &c) in self.codes.iter().enumerate() {
            if validity.is_none_or(|b| b.get(i)) {
                out.push(&self.dict[c as usize]);
            } else {
                out.push("");
            }
        }
        out
    }

    /// Merge `other`'s dictionary into `self`'s, returning the code
    /// remap table for `other`: `remap[old_code] = code in self.dict`.
    /// Entries of `other` unseen in `self` are appended (first-occurrence
    /// order is preserved across the merge), so remapped codes from
    /// either side address one shared dictionary.
    pub fn unify(&mut self, other: &DictUtf8Data) -> Vec<u32> {
        let mut seen: HashMap<&str, u32> = HashMap::with_capacity(self.dict.len());
        for (c, s) in self.dict.iter().enumerate() {
            seen.insert(s.as_str(), c as u32);
        }
        let mut remap = Vec::with_capacity(other.dict.len());
        let mut fresh: Vec<&str> = Vec::new();
        for s in &other.dict {
            match seen.get(s.as_str()) {
                Some(&c) => remap.push(c),
                None => {
                    let c = (self.dict.len() + fresh.len()) as u32;
                    seen.insert(s.as_str(), c);
                    fresh.push(s.as_str());
                    remap.push(c);
                }
            }
        }
        let fresh: Vec<String> = fresh.iter().map(|s| s.to_string()).collect();
        self.dict.extend(fresh);
        remap
    }

    /// Rank of each dictionary entry in lexicographic order:
    /// `rank[code_a] < rank[code_b]  ⇔  dict[code_a] < dict[code_b]`
    /// (entries are unique, so ranks are a permutation). Sort kernels
    /// compare u32 ranks instead of string bytes.
    pub fn sorted_ranks(&self) -> Vec<u32> {
        let mut order: Vec<u32> = (0..self.dict.len() as u32).collect();
        order.sort_by(|&a, &b| self.dict[a as usize].cmp(&self.dict[b as usize]));
        let mut rank = vec![0u32; self.dict.len()];
        for (r, &c) in order.iter().enumerate() {
            rank[c as usize] = r as u32;
        }
        rank
    }
}

/// UTF-8 column payload: `value(i) = bytes[offsets[i]..offsets[i+1]]`.
#[derive(Debug, Clone, PartialEq)]
pub struct Utf8Data {
    pub offsets: Vec<u32>,
    pub bytes: Vec<u8>,
}

impl Utf8Data {
    pub fn empty() -> Self {
        Utf8Data { offsets: vec![0], bytes: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[inline]
    pub fn value(&self, i: usize) -> &str {
        let lo = self.offsets[i] as usize;
        let hi = self.offsets[i + 1] as usize;
        // Safety: builders only append valid UTF-8.
        unsafe { std::str::from_utf8_unchecked(&self.bytes[lo..hi]) }
    }

    pub fn push(&mut self, s: &str) {
        self.bytes.extend_from_slice(s.as_bytes());
        self.offsets.push(self.bytes.len() as u32);
    }

    pub fn from_strs<S: AsRef<str>>(vals: &[S]) -> Self {
        let total: usize = vals.iter().map(|s| s.as_ref().len()).sum();
        let mut d = Utf8Data { offsets: Vec::with_capacity(vals.len() + 1), bytes: Vec::with_capacity(total) };
        d.offsets.push(0);
        for s in vals {
            d.push(s.as_ref());
        }
        d
    }
}

/// A column of data. Cheap to clone? No — clones copy buffers; operators
/// move or borrow. Wrap in `Arc` at the [`Table`](super::table::Table)
/// level when sharing is needed.
#[derive(Debug, Clone, PartialEq)]
pub enum Array {
    Int64(Vec<i64>, Option<Bitmap>),
    Float64(Vec<f64>, Option<Bitmap>),
    Utf8(Utf8Data, Option<Bitmap>),
    /// Dictionary-encoded strings — a physical encoding of logical
    /// [`DataType::Utf8`]; see the module docs and [`DictUtf8Data`].
    DictUtf8(DictUtf8Data, Option<Bitmap>),
    Bool(Vec<bool>, Option<Bitmap>),
    /// Milliseconds since the Unix epoch, UTC.
    Timestamp(Vec<i64>, Option<Bitmap>),
}

impl Array {
    // ---- constructors -------------------------------------------------

    pub fn from_i64(v: Vec<i64>) -> Array {
        Array::Int64(v, None)
    }

    pub fn from_f64(v: Vec<f64>) -> Array {
        Array::Float64(v, None)
    }

    pub fn from_strs<S: AsRef<str>>(v: &[S]) -> Array {
        Array::Utf8(Utf8Data::from_strs(v), None)
    }

    pub fn from_bools(v: Vec<bool>) -> Array {
        Array::Bool(v, None)
    }

    /// Timestamp column from ms-since-epoch values.
    pub fn from_ts(v: Vec<i64>) -> Array {
        Array::Timestamp(v, None)
    }

    /// From options; `None` entries become nulls.
    pub fn from_opt_i64(v: Vec<Option<i64>>) -> Array {
        let mut vals = Vec::with_capacity(v.len());
        let mut bm = Bitmap::new_null(v.len());
        let mut any_null = false;
        for (i, o) in v.into_iter().enumerate() {
            match o {
                Some(x) => {
                    vals.push(x);
                    bm.set(i, true);
                }
                None => {
                    vals.push(0);
                    any_null = true;
                }
            }
        }
        Array::Int64(vals, if any_null { Some(bm) } else { None })
    }

    pub fn from_opt_f64(v: Vec<Option<f64>>) -> Array {
        let mut vals = Vec::with_capacity(v.len());
        let mut bm = Bitmap::new_null(v.len());
        let mut any_null = false;
        for (i, o) in v.into_iter().enumerate() {
            match o {
                Some(x) => {
                    vals.push(x);
                    bm.set(i, true);
                }
                None => {
                    vals.push(0.0);
                    any_null = true;
                }
            }
        }
        Array::Float64(vals, if any_null { Some(bm) } else { None })
    }

    pub fn from_opt_ts(v: Vec<Option<i64>>) -> Array {
        match Array::from_opt_i64(v) {
            Array::Int64(vals, bm) => Array::Timestamp(vals, bm),
            _ => unreachable!(),
        }
    }

    pub fn from_opt_strs(v: Vec<Option<&str>>) -> Array {
        let mut data = Utf8Data::empty();
        let mut bm = Bitmap::new_null(v.len());
        let mut any_null = false;
        for (i, o) in v.into_iter().enumerate() {
            match o {
                Some(s) => {
                    data.push(s);
                    bm.set(i, true);
                }
                None => {
                    data.push("");
                    any_null = true;
                }
            }
        }
        Array::Utf8(data, if any_null { Some(bm) } else { None })
    }

    /// Dictionary-encoded constructor (interned first-occurrence order).
    pub fn dict_from_strs<S: AsRef<str>>(v: &[S]) -> Array {
        Array::from_strs(v).dict_encode()
    }

    /// Re-encode this array's physical layout to [`Array::DictUtf8`].
    /// Identity for non-`Utf8` and already-dictionary arrays. Logical
    /// content is unchanged: encoding round-trips byte-exactly through
    /// [`crate::table::ipc::serialize`] for arrays following the
    /// builder convention of empty null payloads (all arrays produced
    /// by constructors, builders, gathers and concats do).
    pub fn dict_encode(self) -> Array {
        match self {
            Array::Utf8(d, b) => {
                let dict = DictUtf8Data::encode(&d, b.as_ref());
                Array::DictUtf8(dict, b)
            }
            other => other,
        }
    }

    /// Re-encode this array's physical layout to plain [`Array::Utf8`].
    /// Identity for everything but [`Array::DictUtf8`]. Null rows decode
    /// to the empty payload (the builder convention).
    pub fn dict_decode(self) -> Array {
        match self {
            Array::DictUtf8(d, b) => {
                let plain = d.decode(b.as_ref());
                Array::Utf8(plain, b)
            }
            other => other,
        }
    }

    /// True when this array is dictionary-encoded.
    pub fn is_dict(&self) -> bool {
        matches!(self, Array::DictUtf8(..))
    }

    /// Borrowed string payload of cell `i` for either string encoding
    /// (`None` for non-string arrays). Like [`Utf8Data::value`], this
    /// reads the raw slot without consulting validity — null rows yield
    /// the (empty) null payload.
    #[inline]
    pub fn str_at(&self, i: usize) -> Option<&str> {
        match self {
            Array::Utf8(d, _) => Some(d.value(i)),
            Array::DictUtf8(d, _) => Some(d.value(i)),
            _ => None,
        }
    }

    /// An empty array of the given type.
    pub fn empty(dt: DataType) -> Array {
        match dt {
            DataType::Int64 => Array::Int64(Vec::new(), None),
            DataType::Float64 => Array::Float64(Vec::new(), None),
            DataType::Utf8 => Array::Utf8(Utf8Data::empty(), None),
            DataType::Bool => Array::Bool(Vec::new(), None),
            DataType::Timestamp => Array::Timestamp(Vec::new(), None),
        }
    }

    // ---- inspectors ----------------------------------------------------

    /// Logical type. Note [`Array::DictUtf8`] reports [`DataType::Utf8`]:
    /// dictionary encoding is physical and invisible to schemas.
    pub fn data_type(&self) -> DataType {
        match self {
            Array::Int64(..) => DataType::Int64,
            Array::Float64(..) => DataType::Float64,
            Array::Utf8(..) | Array::DictUtf8(..) => DataType::Utf8,
            Array::Bool(..) => DataType::Bool,
            Array::Timestamp(..) => DataType::Timestamp,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Array::Int64(v, _) => v.len(),
            Array::Float64(v, _) => v.len(),
            Array::Utf8(d, _) => d.len(),
            Array::DictUtf8(d, _) => d.len(),
            Array::Bool(v, _) => v.len(),
            Array::Timestamp(v, _) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn validity(&self) -> Option<&Bitmap> {
        match self {
            Array::Int64(_, b)
            | Array::Float64(_, b)
            | Array::Utf8(_, b)
            | Array::DictUtf8(_, b)
            | Array::Bool(_, b)
            | Array::Timestamp(_, b) => b.as_ref(),
        }
    }

    #[inline]
    pub fn is_valid(&self, i: usize) -> bool {
        match self.validity() {
            None => true,
            Some(b) => b.get(i),
        }
    }

    #[inline]
    pub fn is_null(&self, i: usize) -> bool {
        !self.is_valid(i)
    }

    pub fn null_count(&self) -> usize {
        self.validity().map_or(0, |b| b.count_null())
    }

    /// Cell accessor (slow path).
    pub fn get(&self, i: usize) -> Scalar {
        if self.is_null(i) {
            return Scalar::Null;
        }
        match self {
            Array::Int64(v, _) => Scalar::Int64(v[i]),
            Array::Float64(v, _) => Scalar::Float64(v[i]),
            Array::Utf8(d, _) => Scalar::Utf8(d.value(i).to_string()),
            Array::DictUtf8(d, _) => Scalar::Utf8(d.value(i).to_string()),
            Array::Bool(v, _) => Scalar::Bool(v[i]),
            Array::Timestamp(v, _) => Scalar::Timestamp(v[i]),
        }
    }

    // ---- typed views ---------------------------------------------------

    pub fn i64_values(&self) -> Option<&[i64]> {
        match self {
            Array::Int64(v, _) => Some(v),
            _ => None,
        }
    }

    pub fn f64_values(&self) -> Option<&[f64]> {
        match self {
            Array::Float64(v, _) => Some(v),
            _ => None,
        }
    }

    pub fn utf8_data(&self) -> Option<&Utf8Data> {
        match self {
            Array::Utf8(d, _) => Some(d),
            _ => None,
        }
    }

    /// Dictionary payload view (`None` unless [`Array::DictUtf8`]).
    pub fn dict_data(&self) -> Option<&DictUtf8Data> {
        match self {
            Array::DictUtf8(d, _) => Some(d),
            _ => None,
        }
    }

    pub fn bool_values(&self) -> Option<&[bool]> {
        match self {
            Array::Bool(v, _) => Some(v),
            _ => None,
        }
    }

    /// Raw ms-since-epoch view (`None` unless [`Array::Timestamp`]).
    pub fn ts_values(&self) -> Option<&[i64]> {
        match self {
            Array::Timestamp(v, _) => Some(v),
            _ => None,
        }
    }

    /// Numeric view of cell `i`, widening ints; None when null or non-numeric.
    #[inline]
    pub fn f64_at(&self, i: usize) -> Option<f64> {
        if self.is_null(i) {
            return None;
        }
        match self {
            Array::Int64(v, _) => Some(v[i] as f64),
            Array::Float64(v, _) => Some(v[i]),
            _ => None,
        }
    }

    // ---- kernels --------------------------------------------------------

    /// Gather rows by index: `out[k] = self[indices[k]]`.
    ///
    /// The workhorse of select / sort / join materialisation — single
    /// pass, pre-sized output buffers.
    pub fn take(&self, indices: &[usize]) -> Array {
        let validity = self.validity().map(|b| b.take(indices));
        match self {
            Array::Int64(v, _) => {
                let out: Vec<i64> = indices.iter().map(|&i| v[i]).collect();
                Array::Int64(out, validity)
            }
            Array::Float64(v, _) => {
                let out: Vec<f64> = indices.iter().map(|&i| v[i]).collect();
                Array::Float64(out, validity)
            }
            Array::Bool(v, _) => {
                let out: Vec<bool> = indices.iter().map(|&i| v[i]).collect();
                Array::Bool(out, validity)
            }
            Array::Timestamp(v, _) => {
                let out: Vec<i64> = indices.iter().map(|&i| v[i]).collect();
                Array::Timestamp(out, validity)
            }
            Array::Utf8(d, _) => {
                let total: usize = indices
                    .iter()
                    .map(|&i| (d.offsets[i + 1] - d.offsets[i]) as usize)
                    .sum();
                let mut out = Utf8Data {
                    offsets: Vec::with_capacity(indices.len() + 1),
                    bytes: Vec::with_capacity(total),
                };
                out.offsets.push(0);
                for &i in indices {
                    let lo = d.offsets[i] as usize;
                    let hi = d.offsets[i + 1] as usize;
                    out.bytes.extend_from_slice(&d.bytes[lo..hi]);
                    out.offsets.push(out.bytes.len() as u32);
                }
                Array::Utf8(out, validity)
            }
            Array::DictUtf8(d, _) => {
                // Code-space gather: the dictionary rides along untouched.
                let codes: Vec<u32> = indices.iter().map(|&i| d.codes[i]).collect();
                Array::DictUtf8(DictUtf8Data { codes, dict: d.dict.clone() }, validity)
            }
        }
    }

    /// Gather with optional indices: `None` produces a null slot (outer
    /// join materialisation).
    pub fn take_opt(&self, indices: &[Option<usize>]) -> Array {
        use super::builder::ArrayBuilder;
        let mut b = ArrayBuilder::with_capacity(self.data_type(), indices.len());
        for &i in indices {
            match i {
                Some(i) => b.push_from(self, i),
                None => b.push_null(),
            }
        }
        b.finish()
    }

    /// Contiguous slice copy `[start, start+len)`.
    pub fn slice(&self, start: usize, len: usize) -> Array {
        let idx: Vec<usize> = (start..start + len).collect();
        self.take(&idx)
    }

    /// Concatenate many arrays of the same type.
    pub fn concat(arrays: &[&Array]) -> Array {
        assert!(!arrays.is_empty(), "concat of zero arrays");
        let dt = arrays[0].data_type();
        assert!(
            arrays.iter().all(|a| a.data_type() == dt),
            "concat type mismatch"
        );
        let total: usize = arrays.iter().map(|a| a.len()).sum();
        let any_null = arrays.iter().any(|a| a.null_count() > 0);
        let validity = if any_null {
            let mut bm = Bitmap::new_null(total);
            let mut off = 0;
            for a in arrays {
                for i in 0..a.len() {
                    if a.is_valid(i) {
                        bm.set(off + i, true);
                    }
                }
                off += a.len();
            }
            Some(bm)
        } else {
            None
        };
        match dt {
            DataType::Int64 => {
                let mut out = Vec::with_capacity(total);
                for a in arrays {
                    out.extend_from_slice(a.i64_values().unwrap());
                }
                Array::Int64(out, validity)
            }
            DataType::Float64 => {
                let mut out = Vec::with_capacity(total);
                for a in arrays {
                    out.extend_from_slice(a.f64_values().unwrap());
                }
                Array::Float64(out, validity)
            }
            DataType::Bool => {
                let mut out = Vec::with_capacity(total);
                for a in arrays {
                    out.extend_from_slice(a.bool_values().unwrap());
                }
                Array::Bool(out, validity)
            }
            DataType::Timestamp => {
                let mut out = Vec::with_capacity(total);
                for a in arrays {
                    out.extend_from_slice(a.ts_values().unwrap());
                }
                Array::Timestamp(out, validity)
            }
            DataType::Utf8 if arrays.iter().all(|a| a.is_dict()) => {
                // All dictionary-encoded (the shuffle-ingest path):
                // unify dictionaries and remap codes — string bytes are
                // touched once per *distinct* value, not once per row.
                let mut merged = DictUtf8Data { codes: Vec::with_capacity(total), dict: Vec::new() };
                for a in arrays {
                    let d = a.dict_data().unwrap();
                    let remap = merged.unify(d);
                    // `unwrap_or(0)` covers all-null inputs whose empty
                    // dictionary yields an empty remap (codes stay 0).
                    merged
                        .codes
                        .extend(d.codes.iter().map(|&c| remap.get(c as usize).copied().unwrap_or(0)));
                }
                Array::DictUtf8(merged, validity)
            }
            DataType::Utf8 if arrays.iter().any(|a| a.is_dict()) => {
                // Mixed physical encodings: decode to plain and recurse.
                let plains: Vec<Array> = arrays.iter().map(|a| (*a).clone().dict_decode()).collect();
                let refs: Vec<&Array> = plains.iter().collect();
                Array::concat(&refs)
            }
            DataType::Utf8 => {
                let bytes_total: usize = arrays.iter().map(|a| a.utf8_data().unwrap().bytes.len()).sum();
                let mut out = Utf8Data {
                    offsets: Vec::with_capacity(total + 1),
                    bytes: Vec::with_capacity(bytes_total),
                };
                out.offsets.push(0);
                for a in arrays {
                    let d = a.utf8_data().unwrap();
                    let base = out.bytes.len() as u32;
                    out.bytes.extend_from_slice(&d.bytes);
                    out.offsets.extend(d.offsets[1..].iter().map(|o| o + base));
                }
                Array::Utf8(out, validity)
            }
        }
    }

    /// Drop the bitmap if it is all-valid (normalisation after filters).
    pub fn normalize_validity(self) -> Array {
        fn norm(b: Option<Bitmap>) -> Option<Bitmap> {
            b.filter(|bm| !bm.all_valid())
        }
        match self {
            Array::Int64(v, b) => Array::Int64(v, norm(b)),
            Array::Float64(v, b) => Array::Float64(v, norm(b)),
            Array::Utf8(d, b) => Array::Utf8(d, norm(b)),
            Array::DictUtf8(d, b) => Array::DictUtf8(d, norm(b)),
            Array::Bool(v, b) => Array::Bool(v, norm(b)),
            Array::Timestamp(v, b) => Array::Timestamp(v, norm(b)),
        }
    }

    /// Approximate heap footprint in bytes (used by the comm cost model
    /// and the pipeline's backpressure accounting).
    pub fn nbytes(&self) -> usize {
        let bm = self.validity().map_or(0, |b| b.raw().len());
        bm + match self {
            Array::Int64(v, _) => v.len() * 8,
            Array::Float64(v, _) => v.len() * 8,
            Array::Bool(v, _) => v.len(),
            Array::Utf8(d, _) => d.bytes.len() + d.offsets.len() * 4,
            Array::DictUtf8(d, _) => {
                d.codes.len() * 4 + d.dict.iter().map(|s| s.len() + 4).sum::<usize>()
            }
            Array::Timestamp(v, _) => v.len() * 8,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_get() {
        let a = Array::from_opt_i64(vec![Some(1), None, Some(3)]);
        assert_eq!(a.len(), 3);
        assert_eq!(a.null_count(), 1);
        assert_eq!(a.get(0), Scalar::Int64(1));
        assert_eq!(a.get(1), Scalar::Null);
        assert_eq!(a.f64_at(2), Some(3.0));
        assert_eq!(a.f64_at(1), None);
    }

    #[test]
    fn utf8_layout() {
        let a = Array::from_strs(&["ab", "", "xyz"]);
        let d = a.utf8_data().unwrap();
        assert_eq!(d.value(0), "ab");
        assert_eq!(d.value(1), "");
        assert_eq!(d.value(2), "xyz");
        assert_eq!(a.nbytes(), 5 + 4 * 4);
    }

    #[test]
    fn take_gathers_values_and_validity() {
        let a = Array::from_opt_strs(vec![Some("a"), None, Some("c"), Some("d")]);
        let t = a.take(&[3, 1, 0]);
        assert_eq!(t.get(0), Scalar::Utf8("d".into()));
        assert_eq!(t.get(1), Scalar::Null);
        assert_eq!(t.get(2), Scalar::Utf8("a".into()));
    }

    #[test]
    fn concat_mixed_validity() {
        let a = Array::from_i64(vec![1, 2]);
        let b = Array::from_opt_i64(vec![None, Some(4)]);
        let c = Array::concat(&[&a, &b]);
        assert_eq!(c.len(), 4);
        assert_eq!(c.null_count(), 1);
        assert_eq!(c.get(3), Scalar::Int64(4));
        assert_eq!(c.get(2), Scalar::Null);
    }

    #[test]
    fn concat_utf8_offsets_rebased() {
        let a = Array::from_strs(&["aa", "b"]);
        let b = Array::from_strs(&["ccc"]);
        let c = Array::concat(&[&a, &b]);
        assert_eq!(c.get(2), Scalar::Utf8("ccc".into()));
    }

    #[test]
    fn slice_copies_range() {
        let a = Array::from_f64(vec![0.0, 1.0, 2.0, 3.0]);
        let s = a.slice(1, 2);
        assert_eq!(s.f64_values().unwrap(), &[1.0, 2.0]);
    }

    #[test]
    fn normalize_drops_full_bitmap() {
        let mut bm = Bitmap::new_valid(2);
        bm.set(0, true);
        let a = Array::Int64(vec![1, 2], Some(bm)).normalize_validity();
        assert!(a.validity().is_none());
    }

    #[test]
    fn dict_encode_decode_round_trip_with_nulls() {
        let plain = Array::from_opt_strs(vec![Some("b"), None, Some("a"), Some("b"), None]);
        let dict = plain.clone().dict_encode();
        assert_eq!(dict.data_type(), DataType::Utf8, "encoding is invisible to schemas");
        assert_eq!(dict.len(), 5);
        assert_eq!(dict.null_count(), 2);
        let d = dict.dict_data().unwrap();
        assert_eq!(d.dict, vec!["b".to_string(), "a".to_string()], "first-occurrence order");
        assert_eq!(d.codes, vec![0, 0, 1, 0, 0], "nulls carry code 0");
        assert_eq!(dict.get(0), Scalar::Utf8("b".into()));
        assert_eq!(dict.get(1), Scalar::Null);
        assert_eq!(dict.clone().dict_decode(), plain, "decode restores the plain layout");
        // idempotence both ways
        assert_eq!(plain.clone().dict_decode(), plain);
        assert_eq!(dict.clone().dict_encode(), dict);
    }

    #[test]
    fn dict_all_null_column_is_safe() {
        let a = Array::from_opt_strs(vec![None, None]).dict_encode();
        assert!(a.dict_data().unwrap().dict.is_empty());
        assert_eq!(a.get(0), Scalar::Null);
        assert_eq!(a.str_at(1), Some(""), "empty dictionary reads as empty payload");
        let back = a.clone().dict_decode();
        assert_eq!(back, Array::from_opt_strs(vec![None, None]));
        let c = Array::concat(&[&a, &a]);
        assert_eq!(c.len(), 4);
        assert_eq!(c.null_count(), 4);
    }

    #[test]
    fn dict_take_stays_in_code_space() {
        let a = Array::dict_from_strs(&["x", "y", "x", "z"]);
        let t = a.take(&[3, 0, 0]);
        assert!(t.is_dict(), "gather must not decode");
        assert_eq!(t.get(0), Scalar::Utf8("z".into()));
        assert_eq!(t.get(1), Scalar::Utf8("x".into()));
        assert_eq!(t.get(2), Scalar::Utf8("x".into()));
    }

    #[test]
    fn dict_concat_unifies_dictionaries() {
        let a = Array::dict_from_strs(&["p", "q"]);
        let b = Array::dict_from_strs(&["q", "r"]);
        let c = Array::concat(&[&a, &b]);
        assert!(c.is_dict());
        let d = c.dict_data().unwrap();
        assert_eq!(d.dict, vec!["p".to_string(), "q".to_string(), "r".to_string()]);
        assert_eq!(d.codes, vec![0, 1, 1, 2]);
        // mixed encodings decode to plain
        let plain = Array::from_strs(&["s"]);
        let m = Array::concat(&[&a, &plain]);
        assert!(!m.is_dict());
        assert_eq!(m.get(2), Scalar::Utf8("s".into()));
    }

    #[test]
    fn dict_unify_remap_addresses_merged_dict() {
        let mut a = Array::dict_from_strs(&["m", "n"]).dict_data().unwrap().clone();
        let b = Array::dict_from_strs(&["n", "o", "m"]).dict_data().unwrap().clone();
        let remap = a.unify(&b);
        assert_eq!(a.dict, vec!["m".to_string(), "n".to_string(), "o".to_string()]);
        for (old, s) in b.dict.iter().enumerate() {
            assert_eq!(&a.dict[remap[old] as usize], s);
        }
    }

    #[test]
    fn dict_sorted_ranks_are_order_isomorphic() {
        let a = Array::dict_from_strs(&["pear", "apple", "zed", "apple", "fig"]);
        let d = a.dict_data().unwrap();
        let rank = d.sorted_ranks();
        for i in 0..d.dict.len() {
            for j in 0..d.dict.len() {
                assert_eq!(d.dict[i].cmp(&d.dict[j]), rank[i].cmp(&rank[j]));
            }
        }
    }
}
