//! Validity bitmap: one bit per row, 1 = valid, 0 = null.
//!
//! Matches Arrow's semantics: an array with no bitmap is entirely valid.

/// A packed bitmap with LSB-first bit order within each byte.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bitmap {
    bits: Vec<u8>,
    len: usize,
}

impl Bitmap {
    /// An all-valid bitmap of length `len`.
    pub fn new_valid(len: usize) -> Self {
        Bitmap { bits: vec![0xFF; len.div_ceil(8)], len }
    }

    /// An all-null bitmap of length `len`.
    pub fn new_null(len: usize) -> Self {
        Bitmap { bits: vec![0u8; len.div_ceil(8)], len }
    }

    /// Build from a bool slice (`true` = valid).
    pub fn from_bools(v: &[bool]) -> Self {
        let mut bm = Bitmap::new_null(v.len());
        for (i, &b) in v.iter().enumerate() {
            if b {
                bm.set(i, true);
            }
        }
        bm
    }

    /// Reconstruct from raw LSB-first bytes (IPC path).
    pub fn from_raw(bits: Vec<u8>, len: usize) -> Self {
        debug_assert!(bits.len() >= len.div_ceil(8));
        Bitmap { bits, len }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Raw bytes (LSB-first) for IPC.
    pub fn raw(&self) -> &[u8] {
        &self.bits
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.bits[i >> 3] >> (i & 7)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, i: usize, valid: bool) {
        debug_assert!(i < self.len);
        if valid {
            self.bits[i >> 3] |= 1 << (i & 7);
        } else {
            self.bits[i >> 3] &= !(1 << (i & 7));
        }
    }

    /// Append one bit.
    pub fn push(&mut self, valid: bool) {
        if self.len % 8 == 0 {
            self.bits.push(0);
        }
        self.len += 1;
        self.set(self.len - 1, valid);
    }

    /// Number of valid (set) bits.
    pub fn count_valid(&self) -> usize {
        // Mask the trailing partial byte before popcount.
        let full = self.len / 8;
        let mut n: usize = self.bits[..full].iter().map(|b| b.count_ones() as usize).sum();
        let rem = self.len % 8;
        if rem > 0 {
            let mask = (1u16 << rem) as u8 - 1;
            n += (self.bits[full] & mask).count_ones() as usize;
        }
        n
    }

    /// Number of null (unset) bits.
    pub fn count_null(&self) -> usize {
        self.len - self.count_valid()
    }

    /// True when every bit is valid (fast path to drop the bitmap).
    pub fn all_valid(&self) -> bool {
        self.count_valid() == self.len
    }

    /// Gather: new bitmap with `out[k] = self[indices[k]]`.
    pub fn take(&self, indices: &[usize]) -> Bitmap {
        let mut out = Bitmap::new_null(indices.len());
        for (k, &i) in indices.iter().enumerate() {
            if self.get(i) {
                out.set(k, true);
            }
        }
        out
    }

    /// Concatenate two bitmaps.
    pub fn concat(&self, other: &Bitmap) -> Bitmap {
        let mut out = Bitmap::new_null(self.len + other.len);
        for i in 0..self.len {
            if self.get(i) {
                out.set(i, true);
            }
        }
        for i in 0..other.len {
            if other.get(i) {
                out.set(self.len + i, true);
            }
        }
        out
    }

    /// Bitwise AND of two equal-length bitmaps.
    pub fn and(&self, other: &Bitmap) -> Bitmap {
        assert_eq!(self.len, other.len, "bitmap length mismatch");
        let bits = self
            .bits
            .iter()
            .zip(other.bits.iter())
            .map(|(a, b)| a & b)
            .collect();
        Bitmap { bits, len: self.len }
    }

    /// Iterator over bits as bools.
    pub fn iter(&self) -> impl Iterator<Item = bool> + '_ {
        (0..self.len).map(move |i| self.get(i))
    }
}

/// Combine two optional validity bitmaps (None = all valid).
pub fn merge_validity(a: Option<&Bitmap>, b: Option<&Bitmap>, len: usize) -> Option<Bitmap> {
    match (a, b) {
        (None, None) => None,
        (Some(a), None) => Some(a.clone()),
        (None, Some(b)) => Some(b.clone()),
        (Some(a), Some(b)) => {
            debug_assert_eq!(a.len(), len);
            debug_assert_eq!(b.len(), len);
            Some(a.and(b))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_push() {
        let mut bm = Bitmap::new_null(10);
        bm.set(3, true);
        bm.set(9, true);
        assert!(bm.get(3) && bm.get(9) && !bm.get(0));
        assert_eq!(bm.count_valid(), 2);
        bm.push(true);
        assert_eq!(bm.len(), 11);
        assert!(bm.get(10));
        assert_eq!(bm.count_valid(), 3);
    }

    #[test]
    fn counts_with_partial_byte() {
        let bm = Bitmap::new_valid(13);
        assert_eq!(bm.count_valid(), 13);
        assert_eq!(bm.count_null(), 0);
        assert!(bm.all_valid());
    }

    #[test]
    fn take_and_concat() {
        let bm = Bitmap::from_bools(&[true, false, true, true]);
        let t = bm.take(&[3, 1, 0]);
        assert_eq!(t.iter().collect::<Vec<_>>(), vec![true, false, true]);
        let c = bm.concat(&Bitmap::from_bools(&[false, true]));
        assert_eq!(c.len(), 6);
        assert_eq!(c.count_valid(), 4);
    }

    #[test]
    fn and_merge() {
        let a = Bitmap::from_bools(&[true, true, false]);
        let b = Bitmap::from_bools(&[true, false, false]);
        assert_eq!(a.and(&b).iter().collect::<Vec<_>>(), vec![true, false, false]);
        assert!(merge_validity(None, None, 3).is_none());
        let m = merge_validity(Some(&a), Some(&b), 3).unwrap();
        assert_eq!(m.count_valid(), 1);
    }

    #[test]
    fn raw_roundtrip() {
        let bm = Bitmap::from_bools(&[true, false, true, false, true, true, true, false, true]);
        let rt = Bitmap::from_raw(bm.raw().to_vec(), bm.len());
        assert_eq!(bm, rt);
    }
}
