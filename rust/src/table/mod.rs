//! Columnar table substrate (Apache-Arrow-analog).
//!
//! The HPTMT paper's data-engineering side is built on Arrow tables; in
//! this reproduction the substrate is implemented from scratch:
//! validity-bitmap nullable arrays, UTF-8 offset arrays, schemas, typed
//! builders, a CSV front door, an IPC wire format for shuffles, and the
//! shared row-hash/row-equality ([`rowhash`]) and row-order ([`rowcmp`])
//! kernels every hash- or sort-based operator uses.

pub mod array;
pub mod bitmap;
pub mod builder;
pub mod csv;
pub mod ipc;
pub mod pretty;
pub mod rowcmp;
pub mod rowhash;
pub mod scalar;
pub mod schema;
#[allow(clippy::module_inception)]
pub mod table;
pub mod time;

pub use array::{Array, DictUtf8Data};
pub use bitmap::Bitmap;
pub use builder::{ArrayBuilder, TableBuilder};
pub use scalar::{DataType, Scalar};
pub use schema::{Field, Schema, SchemaRef};
pub use table::Table;
