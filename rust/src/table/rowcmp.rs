//! Typed row comparison across tables.
//!
//! This is the ordering counterpart of [`super::rowhash`]: where rowhash
//! gives every hash-based operator one definition of "equal keys",
//! rowcmp gives every order-based operator one definition of "key a
//! sorts before key b" — shared by the local sort kernel and the
//! distributed sample sort, whose splitter rows live in a *different*
//! table (the allgathered sample) than the rows being routed. The f64
//! order is the canonical total order from rowhash (`-0.0 == 0.0`, all
//! NaNs equal and greater than every number), so sorting and hashing
//! never disagree about ties.

use super::array::Array;
use super::rowhash::canonical_f64_total_cmp;
use std::cmp::Ordering;

/// Direction and null placement for one key column — the table-layer
/// spec that `ops::local::sort::SortKey` lowers to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KeyOrder {
    pub ascending: bool,
    /// Where nulls sort. Pandas default is "last" regardless of
    /// direction, and null placement is NOT flipped by `ascending`.
    pub nulls_first: bool,
}

impl KeyOrder {
    pub const ASC: KeyOrder = KeyOrder { ascending: true, nulls_first: false };
    pub const DESC: KeyOrder = KeyOrder { ascending: false, nulls_first: false };
}

/// Compare the valid cells `a[i]` and `b[j]`. The arrays must share a
/// physical type (panics otherwise — callers compare columns of one
/// schema, or of schemas already checked compatible).
#[inline]
pub fn cmp_cells_valid(a: &Array, i: usize, b: &Array, j: usize) -> Ordering {
    match (a, b) {
        (Array::Int64(x, _), Array::Int64(y, _)) => x[i].cmp(&y[j]),
        (Array::Float64(x, _), Array::Float64(y, _)) => canonical_f64_total_cmp(x[i], y[j]),
        (Array::Utf8(x, _), Array::Utf8(y, _)) => x.value(i).cmp(y.value(j)),
        // Dictionary-encoded strings order by value here (the general
        // cross-array path: sample-sort splitters may be plain while
        // the routed rows are dict, or hold two unrelated dictionaries).
        // Same-column sorts take the precomputed-rank fast path in
        // `ops::local::sort` instead of going through this per-cell.
        (Array::DictUtf8(x, _), Array::DictUtf8(y, _)) => x.value(i).cmp(y.value(j)),
        (Array::DictUtf8(x, _), Array::Utf8(y, _)) => x.value(i).cmp(y.value(j)),
        (Array::Utf8(x, _), Array::DictUtf8(y, _)) => x.value(i).cmp(y.value(j)),
        (Array::Bool(x, _), Array::Bool(y, _)) => x[i].cmp(&y[j]),
        (Array::Timestamp(x, _), Array::Timestamp(y, _)) => x[i].cmp(&y[j]),
        _ => panic!("rowcmp: dtype mismatch {} vs {}", a.data_type(), b.data_type()),
    }
}

/// Compare cells `a[i]` and `b[j]` under one key order (null placement
/// applied, then direction).
#[inline]
pub fn cmp_cells(a: &Array, i: usize, b: &Array, j: usize, ord: KeyOrder) -> Ordering {
    match (a.is_valid(i), b.is_valid(j)) {
        (false, false) => Ordering::Equal,
        (false, true) => {
            if ord.nulls_first {
                Ordering::Less
            } else {
                Ordering::Greater
            }
        }
        (true, false) => {
            if ord.nulls_first {
                Ordering::Greater
            } else {
                Ordering::Less
            }
        }
        (true, true) => {
            let o = cmp_cells_valid(a, i, b, j);
            if ord.ascending {
                o
            } else {
                o.reverse()
            }
        }
    }
}

/// Lexicographic comparison of row `i` of the `left` key columns
/// against row `j` of the `right` key columns (parallel column sets,
/// one [`KeyOrder`] per key).
#[inline]
pub fn cmp_rows(
    left: &[&Array],
    i: usize,
    right: &[&Array],
    j: usize,
    orders: &[KeyOrder],
) -> Ordering {
    debug_assert_eq!(left.len(), right.len(), "rowcmp: key column count mismatch");
    debug_assert_eq!(left.len(), orders.len(), "rowcmp: key order count mismatch");
    for ((a, b), ord) in left.iter().zip(right.iter()).zip(orders.iter()) {
        let o = cmp_cells(a, i, b, j, *ord);
        if o != Ordering::Equal {
            return o;
        }
    }
    Ordering::Equal
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn typed_cell_order() {
        let i = Array::from_i64(vec![1, 2]);
        let f = Array::from_f64(vec![0.5, f64::NAN]);
        let s = Array::from_strs(&["ab", "b"]);
        let b = Array::from_bools(vec![false, true]);
        assert_eq!(cmp_cells_valid(&i, 0, &i, 1), Ordering::Less);
        assert_eq!(cmp_cells_valid(&f, 0, &f, 1), Ordering::Less, "NaN sorts last");
        assert_eq!(cmp_cells_valid(&f, 1, &f, 1), Ordering::Equal, "NaNs tie");
        assert_eq!(cmp_cells_valid(&s, 0, &s, 1), Ordering::Less);
        assert_eq!(cmp_cells_valid(&b, 1, &b, 0), Ordering::Greater);
    }

    #[test]
    fn cross_array_comparison() {
        // The sample-sort case: splitter rows live in another array.
        let a = Array::from_strs(&["m"]);
        let b = Array::from_strs(&["a", "m", "z"]);
        assert_eq!(cmp_cells_valid(&a, 0, &b, 0), Ordering::Greater);
        assert_eq!(cmp_cells_valid(&a, 0, &b, 1), Ordering::Equal);
        assert_eq!(cmp_cells_valid(&a, 0, &b, 2), Ordering::Less);
    }

    #[test]
    fn null_placement_and_direction() {
        let a = Array::from_opt_i64(vec![Some(1), None]);
        assert_eq!(cmp_cells(&a, 1, &a, 0, KeyOrder::ASC), Ordering::Greater, "nulls last");
        assert_eq!(cmp_cells(&a, 1, &a, 0, KeyOrder::DESC), Ordering::Greater, "still last");
        let first = KeyOrder { ascending: true, nulls_first: true };
        assert_eq!(cmp_cells(&a, 1, &a, 0, first), Ordering::Less);
        assert_eq!(cmp_cells(&a, 1, &a, 1, KeyOrder::ASC), Ordering::Equal);
        assert_eq!(cmp_cells(&a, 0, &a, 0, KeyOrder::DESC), Ordering::Equal);
    }

    #[test]
    fn lexicographic_rows() {
        let s = Array::from_strs(&["a", "a", "b"]);
        let n = Array::from_i64(vec![2, 1, 0]);
        let cols: Vec<&Array> = vec![&s, &n];
        let asc = [KeyOrder::ASC, KeyOrder::ASC];
        assert_eq!(cmp_rows(&cols, 0, &cols, 1, &asc), Ordering::Greater, "tie broken by n");
        assert_eq!(cmp_rows(&cols, 1, &cols, 2, &asc), Ordering::Less, "first key decides");
        let mixed = [KeyOrder::ASC, KeyOrder::DESC];
        assert_eq!(cmp_rows(&cols, 0, &cols, 1, &mixed), Ordering::Less, "desc second key");
    }

    #[test]
    fn dict_orders_like_plain() {
        let plain = Array::from_strs(&["m", "a", "z", "m"]);
        let dict = plain.clone().dict_encode();
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(
                    cmp_cells_valid(&dict, i, &dict, j),
                    cmp_cells_valid(&plain, i, &plain, j),
                    "dict vs dict at ({i},{j})"
                );
                assert_eq!(
                    cmp_cells_valid(&dict, i, &plain, j),
                    cmp_cells_valid(&plain, i, &plain, j),
                    "dict vs plain at ({i},{j})"
                );
                assert_eq!(
                    cmp_cells_valid(&plain, i, &dict, j),
                    cmp_cells_valid(&plain, i, &plain, j),
                    "plain vs dict at ({i},{j})"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "dtype mismatch")]
    fn mismatched_types_panic() {
        let a = Array::from_i64(vec![1]);
        let b = Array::from_strs(&["x"]);
        cmp_cells_valid(&a, 0, &b, 0);
    }
}
