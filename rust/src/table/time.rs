//! Civil-time conversion for the Timestamp column type.
//!
//! Timestamps are physical `i64` milliseconds since the Unix epoch,
//! UTC, with no leap-second accounting (the POSIX convention Arrow and
//! Pandas share). The parser accepts the ISO-8601 subset the CSV
//! reader infers:
//!
//! * `YYYY-MM-DD` (midnight UTC)
//! * `YYYY-MM-DDTHH:MM:SS` with optional `.m`/`.mm`/`.mmm` fraction
//!   and optional trailing `Z`
//!
//! The formatter emits the canonical form `YYYY-MM-DDTHH:MM:SSZ`
//! (with `.mmm` only when the millisecond part is nonzero), which the
//! parser round-trips, so CSV write → read re-infers Timestamp.
//!
//! Date ↔ day-count conversion uses the proleptic-Gregorian civil
//! algorithms (era/400-year cycle), exact over the whole `i64` ms
//! range; negative timestamps (pre-1970) work through `div_euclid`.

const MS_PER_DAY: i64 = 86_400_000;

/// Days since 1970-01-01 of the civil date `(y, m, d)`; `m` is 1-based.
fn days_from_civil(y: i64, m: u32, d: u32) -> i64 {
    let y = if m <= 2 { y - 1 } else { y };
    let era = if y >= 0 { y } else { y - 399 } / 400;
    let yoe = y - era * 400; // [0, 399]
    let mp = if m > 2 { m - 3 } else { m + 9 } as i64; // [0, 11], Mar=0
    let doy = (153 * mp + 2) / 5 + (d as i64 - 1); // [0, 365]
    let doe = yoe * 365 + yoe / 4 - yoe / 100 + doy; // [0, 146096]
    era * 146097 + doe - 719_468
}

/// Civil date `(y, m, d)` of the day `z` days after 1970-01-01.
fn civil_from_days(z: i64) -> (i64, u32, u32) {
    let z = z + 719_468;
    let era = if z >= 0 { z } else { z - 146_096 } / 146_097;
    let doe = z - era * 146_097; // [0, 146096]
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365; // [0, 399]
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100); // [0, 365]
    let mp = (5 * doy + 2) / 153; // [0, 11]
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32; // [1, 31]
    let m = if mp < 10 { mp + 3 } else { mp - 9 } as u32; // [1, 12]
    (if m <= 2 { y + 1 } else { y }, m, d)
}

fn days_in_month(y: i64, m: u32) -> u32 {
    match m {
        1 | 3 | 5 | 7 | 8 | 10 | 12 => 31,
        4 | 6 | 9 | 11 => 30,
        2 => {
            if y % 4 == 0 && (y % 100 != 0 || y % 400 == 0) {
                29
            } else {
                28
            }
        }
        _ => 0,
    }
}

/// Parse a fixed-width run of ASCII digits.
fn digits(s: &[u8], at: usize, width: usize) -> Option<u64> {
    if at + width > s.len() {
        return None;
    }
    let mut v = 0u64;
    for &b in &s[at..at + width] {
        if !b.is_ascii_digit() {
            return None;
        }
        v = v * 10 + (b - b'0') as u64;
    }
    Some(v)
}

/// Parse the accepted ISO-8601 subset into ms since epoch (UTC), or
/// `None` when `s` is not a timestamp (the CSV inference probe).
pub fn parse_timestamp_ms(s: &str) -> Option<i64> {
    let b = s.as_bytes();
    // date part: YYYY-MM-DD
    if b.len() < 10 || b[4] != b'-' || b[7] != b'-' {
        return None;
    }
    let y = digits(b, 0, 4)? as i64;
    let m = digits(b, 5, 2)? as u32;
    let d = digits(b, 8, 2)? as u32;
    if !(1..=12).contains(&m) || d < 1 || d > days_in_month(y, m) {
        return None;
    }
    let mut ms = days_from_civil(y, m, d) * MS_PER_DAY;
    let mut at = 10;
    if at < b.len() && b[at] == b'T' {
        // time part: HH:MM:SS
        if b.len() < at + 9 || b[at + 3] != b':' || b[at + 6] != b':' {
            return None;
        }
        let hh = digits(b, at + 1, 2)?;
        let mm = digits(b, at + 4, 2)?;
        let ss = digits(b, at + 7, 2)?;
        if hh > 23 || mm > 59 || ss > 59 {
            return None;
        }
        ms += ((hh * 3600 + mm * 60 + ss) * 1000) as i64;
        at += 9;
        if at < b.len() && b[at] == b'.' {
            // 1-3 fraction digits, scaled to milliseconds
            let start = at + 1;
            let mut end = start;
            while end < b.len() && b[end].is_ascii_digit() && end - start < 3 {
                end += 1;
            }
            if end == start {
                return None;
            }
            let frac = digits(b, start, end - start)?;
            ms += (frac * 10u64.pow(3 - (end - start) as u32)) as i64;
            at = end;
        }
    }
    if at < b.len() && b[at] == b'Z' {
        at += 1;
    }
    if at != b.len() {
        return None;
    }
    Some(ms)
}

/// Format ms since epoch as canonical ISO-8601 UTC
/// (`YYYY-MM-DDTHH:MM:SS[.mmm]Z`); inverse of [`parse_timestamp_ms`].
pub fn format_timestamp_ms(ms: i64) -> String {
    let days = ms.div_euclid(MS_PER_DAY);
    let msod = ms.rem_euclid(MS_PER_DAY);
    let (y, m, d) = civil_from_days(days);
    let (hh, mm) = (msod / 3_600_000, (msod / 60_000) % 60);
    let (ss, frac) = ((msod / 1000) % 60, msod % 1000);
    if frac == 0 {
        format!("{y:04}-{m:02}-{d:02}T{hh:02}:{mm:02}:{ss:02}Z")
    } else {
        format!("{y:04}-{m:02}-{d:02}T{hh:02}:{mm:02}:{ss:02}.{frac:03}Z")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_documented_subset() {
        assert_eq!(parse_timestamp_ms("1970-01-01"), Some(0));
        assert_eq!(parse_timestamp_ms("1970-01-02"), Some(MS_PER_DAY));
        assert_eq!(parse_timestamp_ms("1969-12-31"), Some(-MS_PER_DAY));
        assert_eq!(
            parse_timestamp_ms("2021-08-13T09:30:00"),
            Some(1_628_847_000_000)
        );
        assert_eq!(
            parse_timestamp_ms("2021-08-13T09:30:00Z"),
            parse_timestamp_ms("2021-08-13T09:30:00")
        );
        assert_eq!(
            parse_timestamp_ms("2021-08-13T09:30:00.123Z"),
            Some(1_628_847_000_123)
        );
        // short fractions scale: .5 = 500 ms
        assert_eq!(
            parse_timestamp_ms("1970-01-01T00:00:00.5"),
            Some(500)
        );
    }

    #[test]
    fn rejects_non_timestamps() {
        for s in [
            "", "7", "2021", "2021-08", "2021-13-01", "2021-02-30",
            "2021-08-13T25:00:00", "2021-08-13T09:61:00", "2021-08-13 09:30:00",
            "2021-08-13T09:30", "2021-08-13T09:30:00.", "2021-08-13x",
            "2021-08-13T09:30:00Zx", "true", "12.5",
        ] {
            assert_eq!(parse_timestamp_ms(s), None, "{s:?} must not parse");
        }
    }

    #[test]
    fn format_parse_roundtrip() {
        for ms in [
            0i64, 1, 999, 1000, -1, -999, -1000, 1_628_847_000_123,
            -62_135_596_800_000, 253_402_300_799_999,
        ] {
            let s = format_timestamp_ms(ms);
            assert_eq!(parse_timestamp_ms(&s), Some(ms), "{ms} → {s}");
        }
        assert_eq!(format_timestamp_ms(0), "1970-01-01T00:00:00Z");
        assert_eq!(format_timestamp_ms(1_628_847_000_000), "2021-08-13T09:30:00Z");
    }

    #[test]
    fn leap_years_and_month_ends() {
        assert!(parse_timestamp_ms("2020-02-29").is_some());
        assert!(parse_timestamp_ms("2021-02-29").is_none());
        assert!(parse_timestamp_ms("2000-02-29").is_some());
        assert!(parse_timestamp_ms("1900-02-29").is_none());
        // day arithmetic agrees with the formatter across a leap day
        let feb29 = parse_timestamp_ms("2020-02-29T12:00:00").unwrap();
        assert_eq!(format_timestamp_ms(feb29 + MS_PER_DAY), "2020-03-01T12:00:00Z");
    }
}
