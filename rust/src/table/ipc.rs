//! Byte-level (de)serialisation of tables — the "wire format" used by
//! the shuffle path and the multi-node simulation.
//!
//! Layout (little-endian):
//! ```text
//! magic "HPT1"           4 bytes
//! ncols: u32, nrows: u64
//! per column:
//!   name_len: u32, name bytes
//!   dtype tag: u8
//!   has_validity: u8
//!   [validity bytes: ceil(nrows/8)]
//!   payload:
//!     int64/float64/timestamp: nrows * 8 bytes
//!     bool: nrows bytes
//!     utf8: offsets (nrows+1)*4 bytes, byte_len u64, bytes
//! ```
//!
//! Going through real bytes (rather than handing `Arc<Table>` across the
//! channel) is deliberate: it charges the benchmark the serialisation
//! cost an MPI shuffle pays, and gives the comm cost model exact message
//! sizes.
//!
//! Two entry points with different canonicalisation contracts:
//!
//! * [`serialize`] / [`deserialize`] — the *canonical* format above.
//!   Dictionary-encoded columns are expanded to plain `Utf8` payloads
//!   (null slots as empty strings), so two tables with equal logical
//!   content serialise to equal bytes regardless of physical encoding.
//!   Every differential wall compares at this level.
//! * [`serialize_wire`] / [`deserialize_wire`] — the *shuffle* format.
//!   Dictionary columns keep their encoding on the wire (tag 4: unique
//!   entries once + u32 codes per row), which is strictly smaller than
//!   the plain payload whenever values repeat. [`DictWireState`] extends
//!   this to streaming edges: after the first batch only dictionary
//!   *deltas* ship, so a stable dictionary costs zero string bytes per
//!   subsequent batch.

use super::array::{Array, DictUtf8Data, Utf8Data};
use super::bitmap::Bitmap;
use super::scalar::DataType;
use super::schema::{Field, Schema};
use super::table::Table;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;

const MAGIC: &[u8; 4] = b"HPT1";
/// Magic for the streaming dict-delta format ([`DictWireState`]).
const DELTA_MAGIC: &[u8; 4] = b"HPTD";
/// Wire-only encoding tag for dictionary-encoded `Utf8` columns. Not a
/// [`DataType`] tag: `DataType::from_tag(4)` is `None`, so the canonical
/// format can never contain it.
const DICT_TAG: u8 = 4;

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // `len - pos` (not `pos + n > len`) so a hostile length word
        // near usize::MAX can't wrap the comparison around.
        if self.buf.len() - self.pos < n {
            bail!("ipc: truncated buffer (want {n} at {}, have {})", self.pos, self.buf.len());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    /// Take `count * width` bytes, rejecting multiplication overflow —
    /// a wire-declared row count near u64::MAX must fail cleanly, not
    /// wrap into a small (and wrong) payload size.
    fn take_n(&mut self, count: usize, width: usize) -> Result<&'a [u8]> {
        let n = count
            .checked_mul(width)
            .with_context(|| format!("ipc: {count} x {width}-byte payload overflows"))?;
        self.take(n)
    }
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// Serialise a table to bytes.
pub fn serialize(table: &Table) -> Vec<u8> {
    let nrows = table.num_rows();
    let mut w = Writer { buf: Vec::with_capacity(table.nbytes() + 64) };
    w.bytes(MAGIC);
    w.u32(table.num_columns() as u32);
    w.u64(nrows as u64);
    for (field, col) in table.schema().fields().iter().zip(table.columns()) {
        w.u32(field.name.len() as u32);
        w.bytes(field.name.as_bytes());
        w.u8(field.data_type.tag());
        match col.validity() {
            Some(bm) => {
                w.u8(1);
                w.bytes(&bm.raw()[..nrows.div_ceil(8)]);
            }
            None => w.u8(0),
        }
        match col {
            Array::Int64(v, _) | Array::Timestamp(v, _) => {
                for x in v {
                    w.bytes(&x.to_le_bytes());
                }
            }
            Array::Float64(v, _) => {
                for x in v {
                    w.bytes(&x.to_le_bytes());
                }
            }
            Array::Bool(v, _) => {
                for &x in v {
                    w.u8(x as u8);
                }
            }
            Array::Utf8(d, _) => {
                for o in &d.offsets {
                    w.bytes(&o.to_le_bytes());
                }
                w.u64(d.bytes.len() as u64);
                w.bytes(&d.bytes);
            }
            Array::DictUtf8(d, _) => {
                // Canonicalise: expand to the plain payload (null rows
                // as empty strings) so serialize-level equality is
                // independent of physical encoding.
                let plain = d.decode(col.validity());
                for o in &plain.offsets {
                    w.bytes(&o.to_le_bytes());
                }
                w.u64(plain.bytes.len() as u64);
                w.bytes(&plain.bytes);
            }
        }
    }
    w.buf
}

/// Deserialise a table from bytes produced by [`serialize`].
pub fn deserialize(buf: &[u8]) -> Result<Table> {
    let mut r = Reader { buf, pos: 0 };
    if r.take(4)? != MAGIC {
        bail!("ipc: bad magic");
    }
    let ncols = r.u32()? as usize;
    let nrows = r.u64()? as usize;
    // Column headers cost >= 6 bytes each; cap the preallocation by
    // what the buffer can actually contain (hostile-count defense).
    let mut fields = Vec::with_capacity(ncols.min(r.remaining() / 6));
    let mut columns = Vec::with_capacity(ncols.min(r.remaining() / 6));
    for c in 0..ncols {
        let name_len = r.u32()? as usize;
        let name = std::str::from_utf8(r.take(name_len)?)
            .with_context(|| format!("ipc: column {c} name not utf8"))?
            .to_string();
        let dt = DataType::from_tag(r.u8()?).context("ipc: bad dtype tag")?;
        let validity = if r.u8()? == 1 {
            let raw = r.take(nrows.div_ceil(8))?.to_vec();
            Some(Bitmap::from_raw(raw, nrows))
        } else {
            None
        };
        let arr = match dt {
            DataType::Int64 => {
                let raw = r.take_n(nrows, 8)?;
                let v = raw
                    .chunks_exact(8)
                    .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Array::Int64(v, validity)
            }
            DataType::Timestamp => {
                let raw = r.take_n(nrows, 8)?;
                let v = raw
                    .chunks_exact(8)
                    .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Array::Timestamp(v, validity)
            }
            DataType::Float64 => {
                let raw = r.take_n(nrows, 8)?;
                let v = raw
                    .chunks_exact(8)
                    .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Array::Float64(v, validity)
            }
            DataType::Bool => {
                let raw = r.take(nrows)?;
                Array::Bool(raw.iter().map(|&b| b != 0).collect(), validity)
            }
            DataType::Utf8 => {
                let raw =
                    r.take_n(nrows.checked_add(1).context("ipc: row count overflows")?, 4)?;
                let offsets: Vec<u32> = raw
                    .chunks_exact(4)
                    .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                let blen = r.u64()? as usize;
                let bytes = r.take(blen)?.to_vec();
                Array::Utf8(Utf8Data { offsets, bytes }, validity)
            }
        };
        fields.push(Field::new(name, dt));
        columns.push(arr);
    }
    if r.pos != buf.len() {
        bail!("ipc: {} trailing bytes", buf.len() - r.pos);
    }
    Table::new(Schema::new(fields), columns)
}

// ---------------------------------------------------------------------------
// Shuffle wire format: dictionary columns stay encoded on the wire.
// ---------------------------------------------------------------------------

fn write_dict_entries(w: &mut Writer, entries: &[String]) {
    w.u32(entries.len() as u32);
    for s in entries {
        w.u32(s.len() as u32);
        w.bytes(s.as_bytes());
    }
}

fn read_dict_entries(r: &mut Reader<'_>) -> Result<Vec<String>> {
    let n = r.u32()? as usize;
    // Capacity capped by what the buffer could possibly hold (each
    // entry costs at least its 4-byte length word): a hostile count
    // can make the loop fail on a truncated read, never pre-allocate
    // gigabytes.
    let mut out = Vec::with_capacity(n.min(r.remaining() / 4));
    for i in 0..n {
        let len = r.u32()? as usize;
        let s = std::str::from_utf8(r.take(len)?)
            .with_context(|| format!("ipc: dict entry {i} not utf8"))?;
        out.push(s.to_string());
    }
    Ok(out)
}

fn write_codes(w: &mut Writer, codes: &[u32]) {
    for c in codes {
        w.bytes(&c.to_le_bytes());
    }
}

fn read_codes(r: &mut Reader<'_>, nrows: usize) -> Result<Vec<u32>> {
    let raw = r.take_n(nrows, 4)?;
    Ok(raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
}

/// Serialise for the shuffle wire: identical to [`serialize`] except
/// that [`Array::DictUtf8`] columns keep their encoding (tag
/// [`DICT_TAG`]): the unique entries ship once, rows ship as u32 codes.
/// Strictly fewer bytes than the canonical payload whenever the column
/// has repeated values. Plain columns produce byte-identical output to
/// [`serialize`], so the formats only diverge when a dictionary is
/// actually present.
pub fn serialize_wire(table: &Table) -> Vec<u8> {
    let nrows = table.num_rows();
    let mut w = Writer { buf: Vec::with_capacity(table.nbytes() + 64) };
    w.bytes(MAGIC);
    w.u32(table.num_columns() as u32);
    w.u64(nrows as u64);
    for (field, col) in table.schema().fields().iter().zip(table.columns()) {
        w.u32(field.name.len() as u32);
        w.bytes(field.name.as_bytes());
        match col {
            Array::DictUtf8(..) => w.u8(DICT_TAG),
            _ => w.u8(field.data_type.tag()),
        }
        match col.validity() {
            Some(bm) => {
                w.u8(1);
                w.bytes(&bm.raw()[..nrows.div_ceil(8)]);
            }
            None => w.u8(0),
        }
        match col {
            Array::Int64(v, _) | Array::Timestamp(v, _) => {
                for x in v {
                    w.bytes(&x.to_le_bytes());
                }
            }
            Array::Float64(v, _) => {
                for x in v {
                    w.bytes(&x.to_le_bytes());
                }
            }
            Array::Bool(v, _) => {
                for &x in v {
                    w.u8(x as u8);
                }
            }
            Array::Utf8(d, _) => {
                for o in &d.offsets {
                    w.bytes(&o.to_le_bytes());
                }
                w.u64(d.bytes.len() as u64);
                w.bytes(&d.bytes);
            }
            Array::DictUtf8(d, _) => {
                write_dict_entries(&mut w, &d.dict);
                write_codes(&mut w, &d.codes);
            }
        }
    }
    w.buf
}

/// Deserialise bytes produced by [`serialize_wire`]. Dictionary columns
/// come back as [`Array::DictUtf8`] (the receive path unifies them on
/// concat); plain columns exactly as from [`deserialize`].
pub fn deserialize_wire(buf: &[u8]) -> Result<Table> {
    let mut r = Reader { buf, pos: 0 };
    if r.take(4)? != MAGIC {
        bail!("ipc: bad magic");
    }
    let ncols = r.u32()? as usize;
    let nrows = r.u64()? as usize;
    // Column headers cost >= 6 bytes each; cap the preallocation by
    // what the buffer can actually contain (hostile-count defense).
    let mut fields = Vec::with_capacity(ncols.min(r.remaining() / 6));
    let mut columns = Vec::with_capacity(ncols.min(r.remaining() / 6));
    for c in 0..ncols {
        let name_len = r.u32()? as usize;
        let name = std::str::from_utf8(r.take(name_len)?)
            .with_context(|| format!("ipc: column {c} name not utf8"))?
            .to_string();
        let tag = r.u8()?;
        let validity = if r.u8()? == 1 {
            let raw = r.take(nrows.div_ceil(8))?.to_vec();
            Some(Bitmap::from_raw(raw, nrows))
        } else {
            None
        };
        if tag == DICT_TAG {
            let dict = read_dict_entries(&mut r)?;
            let codes = read_codes(&mut r, nrows)?;
            for (i, &code) in codes.iter().enumerate() {
                let valid = validity.as_ref().is_none_or(|b| b.get(i));
                if valid && code as usize >= dict.len() {
                    bail!("ipc: dict code {code} out of range ({} entries)", dict.len());
                }
            }
            fields.push(Field::new(name, DataType::Utf8));
            columns.push(Array::DictUtf8(DictUtf8Data { codes, dict }, validity));
            continue;
        }
        let dt = DataType::from_tag(tag).context("ipc: bad dtype tag")?;
        let arr = match dt {
            DataType::Int64 => {
                let raw = r.take_n(nrows, 8)?;
                let v = raw
                    .chunks_exact(8)
                    .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Array::Int64(v, validity)
            }
            DataType::Timestamp => {
                let raw = r.take_n(nrows, 8)?;
                let v = raw
                    .chunks_exact(8)
                    .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Array::Timestamp(v, validity)
            }
            DataType::Float64 => {
                let raw = r.take_n(nrows, 8)?;
                let v = raw
                    .chunks_exact(8)
                    .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Array::Float64(v, validity)
            }
            DataType::Bool => {
                let raw = r.take(nrows)?;
                Array::Bool(raw.iter().map(|&b| b != 0).collect(), validity)
            }
            DataType::Utf8 => {
                let raw =
                    r.take_n(nrows.checked_add(1).context("ipc: row count overflows")?, 4)?;
                let offsets: Vec<u32> = raw
                    .chunks_exact(4)
                    .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                let blen = r.u64()? as usize;
                let bytes = r.take(blen)?.to_vec();
                Array::Utf8(Utf8Data { offsets, bytes }, validity)
            }
        };
        fields.push(Field::new(name, dt));
        columns.push(arr);
    }
    if r.pos != buf.len() {
        bail!("ipc: {} trailing bytes", buf.len() - r.pos);
    }
    Table::new(Schema::new(fields), columns)
}

/// Per-edge dictionary state for streaming sends: the sender and the
/// receiver each hold one `DictWireState` per (edge) and the sender's
/// [`DictWireState::encode_batch`] ships only the dictionary entries
/// the paired receiver has not seen yet. When a column's dictionary is
/// stable across batches (the common case: batches sliced from one
/// encoded table share one dictionary), every batch after the first
/// carries **zero** string bytes for that column — codes only.
///
/// Delta rule per dictionary column: if the batch dictionary extends
/// the shipped entries as a prefix, only the tail ships (`base` = how
/// many entries the receiver already holds); otherwise the state
/// resyncs (`base` = 0, full dictionary ships). Plain columns are
/// unaffected and use the [`serialize_wire`] payloads.
#[derive(Debug, Default)]
pub struct DictWireState {
    /// Per-column entries the peer holds, in shipped order.
    shipped: HashMap<String, Vec<String>>,
}

impl DictWireState {
    pub fn new() -> DictWireState {
        DictWireState::default()
    }

    /// Sender side: encode one batch, shipping dictionary deltas only.
    pub fn encode_batch(&mut self, table: &Table) -> Vec<u8> {
        let nrows = table.num_rows();
        let mut w = Writer { buf: Vec::with_capacity(table.nbytes() / 2 + 64) };
        w.bytes(DELTA_MAGIC);
        w.u32(table.num_columns() as u32);
        w.u64(nrows as u64);
        for (field, col) in table.schema().fields().iter().zip(table.columns()) {
            w.u32(field.name.len() as u32);
            w.bytes(field.name.as_bytes());
            match col {
                Array::DictUtf8(..) => w.u8(DICT_TAG),
                _ => w.u8(field.data_type.tag()),
            }
            match col.validity() {
                Some(bm) => {
                    w.u8(1);
                    w.bytes(&bm.raw()[..nrows.div_ceil(8)]);
                }
                None => w.u8(0),
            }
            match col {
                Array::Int64(v, _) | Array::Timestamp(v, _) => {
                    for x in v {
                        w.bytes(&x.to_le_bytes());
                    }
                }
                Array::Float64(v, _) => {
                    for x in v {
                        w.bytes(&x.to_le_bytes());
                    }
                }
                Array::Bool(v, _) => {
                    for &x in v {
                        w.u8(x as u8);
                    }
                }
                Array::Utf8(d, _) => {
                    for o in &d.offsets {
                        w.bytes(&o.to_le_bytes());
                    }
                    w.u64(d.bytes.len() as u64);
                    w.bytes(&d.bytes);
                }
                Array::DictUtf8(d, _) => {
                    let cache = self.shipped.entry(field.name.clone()).or_default();
                    let is_prefix =
                        d.dict.len() >= cache.len() && d.dict[..cache.len()] == cache[..];
                    let base = if is_prefix {
                        cache.len()
                    } else {
                        cache.clear();
                        0
                    };
                    w.u32(base as u32);
                    write_dict_entries(&mut w, &d.dict[base..]);
                    cache.extend(d.dict[base..].iter().cloned());
                    write_codes(&mut w, &d.codes);
                }
            }
        }
        w.buf
    }

    /// Receiver side: decode a batch produced by the sender's paired
    /// state. Batches must arrive in send order (per edge), or the
    /// dictionary bases will not line up and decoding fails.
    pub fn decode_batch(&mut self, buf: &[u8]) -> Result<Table> {
        let mut r = Reader { buf, pos: 0 };
        if r.take(4)? != DELTA_MAGIC {
            bail!("ipc: bad dict-delta magic");
        }
        let ncols = r.u32()? as usize;
        let nrows = r.u64()? as usize;
        let mut fields = Vec::with_capacity(ncols.min(r.remaining() / 6));
        let mut columns = Vec::with_capacity(ncols.min(r.remaining() / 6));
        for c in 0..ncols {
            let name_len = r.u32()? as usize;
            let name = std::str::from_utf8(r.take(name_len)?)
                .with_context(|| format!("ipc: column {c} name not utf8"))?
                .to_string();
            let tag = r.u8()?;
            let validity = if r.u8()? == 1 {
                let raw = r.take(nrows.div_ceil(8))?.to_vec();
                Some(Bitmap::from_raw(raw, nrows))
            } else {
                None
            };
            if tag == DICT_TAG {
                let base = r.u32()? as usize;
                let fresh = read_dict_entries(&mut r)?;
                let codes = read_codes(&mut r, nrows)?;
                let cache = self.shipped.entry(name.clone()).or_default();
                if base > cache.len() {
                    bail!(
                        "ipc: dict delta base {base} ahead of receiver state ({} entries) — \
                         batches decoded out of order?",
                        cache.len()
                    );
                }
                cache.truncate(base);
                cache.extend(fresh);
                let dict = cache.clone();
                for (i, &code) in codes.iter().enumerate() {
                    let valid = validity.as_ref().is_none_or(|b| b.get(i));
                    if valid && code as usize >= dict.len() {
                        bail!("ipc: dict code {code} out of range ({} entries)", dict.len());
                    }
                }
                fields.push(Field::new(name, DataType::Utf8));
                columns.push(Array::DictUtf8(DictUtf8Data { codes, dict }, validity));
                continue;
            }
            let dt = DataType::from_tag(tag).context("ipc: bad dtype tag")?;
            let arr = match dt {
                DataType::Int64 => {
                    let raw = r.take_n(nrows, 8)?;
                    let v = raw
                        .chunks_exact(8)
                        .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    Array::Int64(v, validity)
                }
                DataType::Timestamp => {
                    let raw = r.take_n(nrows, 8)?;
                    let v = raw
                        .chunks_exact(8)
                        .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    Array::Timestamp(v, validity)
                }
                DataType::Float64 => {
                    let raw = r.take_n(nrows, 8)?;
                    let v = raw
                        .chunks_exact(8)
                        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    Array::Float64(v, validity)
                }
                DataType::Bool => {
                    let raw = r.take(nrows)?;
                    Array::Bool(raw.iter().map(|&b| b != 0).collect(), validity)
                }
                DataType::Utf8 => {
                    let raw =
                    r.take_n(nrows.checked_add(1).context("ipc: row count overflows")?, 4)?;
                    let offsets: Vec<u32> = raw
                        .chunks_exact(4)
                        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    let blen = r.u64()? as usize;
                    let bytes = r.take(blen)?.to_vec();
                    Array::Utf8(Utf8Data { offsets, bytes }, validity)
                }
            };
            fields.push(Field::new(name, dt));
            columns.push(arr);
        }
        if r.pos != buf.len() {
            bail!("ipc: {} trailing bytes", buf.len() - r.pos);
        }
        Table::new(Schema::new(fields), columns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::table::scalar::Scalar;

    fn sample() -> Table {
        Table::from_columns(vec![
            ("id", Array::from_opt_i64(vec![Some(1), None, Some(3)])),
            ("name", Array::from_opt_strs(vec![Some("aa"), Some(""), None])),
            ("score", Array::from_f64(vec![0.5, 1.5, -2.5])),
            ("flag", Array::from_bools(vec![true, false, true])),
            ("ts", Array::from_opt_ts(vec![Some(0), Some(1_628_847_000_123), None])),
        ])
        .unwrap()
    }

    #[test]
    fn roundtrip() {
        let t = sample();
        let bytes = serialize(&t);
        let rt = deserialize(&bytes).unwrap();
        assert_eq!(t, rt);
        assert_eq!(rt.cell(1, 0), Scalar::Null);
        assert_eq!(rt.cell(0, 1), Scalar::Utf8("aa".into()));
        assert_eq!(rt.cell(1, 4), Scalar::Timestamp(1_628_847_000_123));
        assert_eq!(rt.cell(2, 4), Scalar::Null);
    }

    #[test]
    fn hostile_length_words_error_without_overallocating() {
        // A crashed or malicious peer can put anything in the length
        // words; every decoder must fail cleanly in O(1) memory.
        let t = sample().dict_encode_columns();
        let wire = serialize_wire(&t);
        // Row count -> u64::MAX (offset 8, after the 4-byte magic and
        // u32 ncols): `nrows * 8` must not wrap.
        let mut huge_rows = wire.clone();
        huge_rows[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(deserialize_wire(&huge_rows).is_err());
        assert!(deserialize(&serialize(&sample())[..0]).is_err(), "empty buffer");
        let mut huge_rows_canon = serialize(&sample());
        huge_rows_canon[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(deserialize(&huge_rows_canon).is_err());
        // Column count -> u32::MAX: the Vec preallocation is capped by
        // the buffer length, so this errors on a truncated header read
        // instead of reserving gigabytes.
        let mut huge_cols = wire.clone();
        huge_cols[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(deserialize_wire(&huge_cols).is_err());
        // Truncation at every prefix: total, never a panic.
        for cut in 0..wire.len() {
            assert!(deserialize_wire(&wire[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn empty_table_roundtrip() {
        let t = sample().slice(0, 0);
        let rt = deserialize(&serialize(&t)).unwrap();
        assert_eq!(rt.num_rows(), 0);
        assert_eq!(rt.num_columns(), 5);
    }

    #[test]
    fn corrupt_inputs_rejected() {
        assert!(deserialize(b"nope").is_err());
        let mut bytes = serialize(&sample());
        bytes.truncate(bytes.len() - 3);
        assert!(deserialize(&bytes).is_err());
        let mut extra = serialize(&sample());
        extra.push(0);
        assert!(deserialize(&extra).is_err());
    }

    /// A keyed table in both encodings: `name` repeats heavily.
    fn encoded_pair() -> (Table, Table) {
        let names: Vec<Option<&str>> = (0..40)
            .map(|i| if i % 10 == 3 { None } else { Some(["alpha", "beta", "gamma"][i % 3]) })
            .collect();
        let plain = Table::from_columns(vec![
            ("name", Array::from_opt_strs(names)),
            ("v", Array::from_i64((0..40).collect())),
        ])
        .unwrap();
        let dict = plain.dict_encode_columns();
        (plain, dict)
    }

    #[test]
    fn canonical_serialize_is_encoding_invariant() {
        let (plain, dict) = encoded_pair();
        assert_eq!(serialize(&plain), serialize(&dict));
        // and the canonical bytes decode to the plain layout
        let rt = deserialize(&serialize(&dict)).unwrap();
        assert_eq!(rt, plain);
    }

    #[test]
    fn wire_roundtrip_preserves_dict_and_saves_bytes() {
        let (plain, dict) = encoded_pair();
        let wire = serialize_wire(&dict);
        let rt = deserialize_wire(&wire).unwrap();
        assert_eq!(rt, dict, "wire round-trip keeps the dictionary encoding");
        assert!(rt.columns()[0].is_dict());
        // dictionary wire payload beats the canonical expansion
        assert!(
            wire.len() < serialize(&plain).len(),
            "dict wire {} !< plain {}",
            wire.len(),
            serialize(&plain).len()
        );
        // plain tables serialise identically under both formats
        assert_eq!(serialize_wire(&plain), serialize(&plain));
        // canonical deserialize must reject the dict tag
        assert!(deserialize(&wire).is_err());
    }

    #[test]
    fn dict_delta_state_ships_dictionary_once() {
        let (_, dict) = encoded_pair();
        let (b1, b2) = (dict.slice(0, 20), dict.slice(20, 20));
        let mut tx = DictWireState::new();
        let mut rx = DictWireState::new();
        let w1 = tx.encode_batch(&b1);
        let w2 = tx.encode_batch(&b2);
        assert!(
            w2.len() < w1.len(),
            "second batch must ship no dictionary entries ({} !< {})",
            w2.len(),
            w1.len()
        );
        assert_eq!(rx.decode_batch(&w1).unwrap(), b1);
        assert_eq!(rx.decode_batch(&w2).unwrap(), b2);
        // out-of-order decode on a fresh receiver fails loudly
        let mut cold = DictWireState::new();
        assert!(cold.decode_batch(&w2).is_err());
    }

    #[test]
    fn dict_delta_state_resyncs_on_dictionary_change() {
        let a = Table::from_columns(vec![("k", Array::dict_from_strs(&["x", "y", "x"]))]).unwrap();
        let b = Table::from_columns(vec![("k", Array::dict_from_strs(&["z", "z", "y"]))]).unwrap();
        let mut tx = DictWireState::new();
        let mut rx = DictWireState::new();
        for t in [&a, &b, &a] {
            let wire = tx.encode_batch(t);
            assert_eq!(&rx.decode_batch(&wire).unwrap(), t);
        }
    }
}
