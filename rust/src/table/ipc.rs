//! Byte-level (de)serialisation of tables — the "wire format" used by
//! the shuffle path and the multi-node simulation.
//!
//! Layout (little-endian):
//! ```text
//! magic "HPT1"           4 bytes
//! ncols: u32, nrows: u64
//! per column:
//!   name_len: u32, name bytes
//!   dtype tag: u8
//!   has_validity: u8
//!   [validity bytes: ceil(nrows/8)]
//!   payload:
//!     int64/float64: nrows * 8 bytes
//!     bool: nrows bytes
//!     utf8: offsets (nrows+1)*4 bytes, byte_len u64, bytes
//! ```
//!
//! Going through real bytes (rather than handing `Arc<Table>` across the
//! channel) is deliberate: it charges the benchmark the serialisation
//! cost an MPI shuffle pays, and gives the comm cost model exact message
//! sizes.

use super::array::{Array, Utf8Data};
use super::bitmap::Bitmap;
use super::scalar::DataType;
use super::schema::{Field, Schema};
use super::table::Table;
use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 4] = b"HPT1";

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("ipc: truncated buffer (want {n} at {}, have {})", self.pos, self.buf.len());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// Serialise a table to bytes.
pub fn serialize(table: &Table) -> Vec<u8> {
    let nrows = table.num_rows();
    let mut w = Writer { buf: Vec::with_capacity(table.nbytes() + 64) };
    w.bytes(MAGIC);
    w.u32(table.num_columns() as u32);
    w.u64(nrows as u64);
    for (field, col) in table.schema().fields().iter().zip(table.columns()) {
        w.u32(field.name.len() as u32);
        w.bytes(field.name.as_bytes());
        w.u8(field.data_type.tag());
        match col.validity() {
            Some(bm) => {
                w.u8(1);
                w.bytes(&bm.raw()[..nrows.div_ceil(8)]);
            }
            None => w.u8(0),
        }
        match col {
            Array::Int64(v, _) => {
                for x in v {
                    w.bytes(&x.to_le_bytes());
                }
            }
            Array::Float64(v, _) => {
                for x in v {
                    w.bytes(&x.to_le_bytes());
                }
            }
            Array::Bool(v, _) => {
                for &x in v {
                    w.u8(x as u8);
                }
            }
            Array::Utf8(d, _) => {
                for o in &d.offsets {
                    w.bytes(&o.to_le_bytes());
                }
                w.u64(d.bytes.len() as u64);
                w.bytes(&d.bytes);
            }
        }
    }
    w.buf
}

/// Deserialise a table from bytes produced by [`serialize`].
pub fn deserialize(buf: &[u8]) -> Result<Table> {
    let mut r = Reader { buf, pos: 0 };
    if r.take(4)? != MAGIC {
        bail!("ipc: bad magic");
    }
    let ncols = r.u32()? as usize;
    let nrows = r.u64()? as usize;
    let mut fields = Vec::with_capacity(ncols);
    let mut columns = Vec::with_capacity(ncols);
    for c in 0..ncols {
        let name_len = r.u32()? as usize;
        let name = std::str::from_utf8(r.take(name_len)?)
            .with_context(|| format!("ipc: column {c} name not utf8"))?
            .to_string();
        let dt = DataType::from_tag(r.u8()?).context("ipc: bad dtype tag")?;
        let validity = if r.u8()? == 1 {
            let raw = r.take(nrows.div_ceil(8))?.to_vec();
            Some(Bitmap::from_raw(raw, nrows))
        } else {
            None
        };
        let arr = match dt {
            DataType::Int64 => {
                let raw = r.take(nrows * 8)?;
                let v = raw
                    .chunks_exact(8)
                    .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Array::Int64(v, validity)
            }
            DataType::Float64 => {
                let raw = r.take(nrows * 8)?;
                let v = raw
                    .chunks_exact(8)
                    .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Array::Float64(v, validity)
            }
            DataType::Bool => {
                let raw = r.take(nrows)?;
                Array::Bool(raw.iter().map(|&b| b != 0).collect(), validity)
            }
            DataType::Utf8 => {
                let raw = r.take((nrows + 1) * 4)?;
                let offsets: Vec<u32> = raw
                    .chunks_exact(4)
                    .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                let blen = r.u64()? as usize;
                let bytes = r.take(blen)?.to_vec();
                Array::Utf8(Utf8Data { offsets, bytes }, validity)
            }
        };
        fields.push(Field::new(name, dt));
        columns.push(arr);
    }
    if r.pos != buf.len() {
        bail!("ipc: {} trailing bytes", buf.len() - r.pos);
    }
    Table::new(Schema::new(fields), columns)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::table::scalar::Scalar;

    fn sample() -> Table {
        Table::from_columns(vec![
            ("id", Array::from_opt_i64(vec![Some(1), None, Some(3)])),
            ("name", Array::from_opt_strs(vec![Some("aa"), Some(""), None])),
            ("score", Array::from_f64(vec![0.5, 1.5, -2.5])),
            ("flag", Array::from_bools(vec![true, false, true])),
        ])
        .unwrap()
    }

    #[test]
    fn roundtrip() {
        let t = sample();
        let bytes = serialize(&t);
        let rt = deserialize(&bytes).unwrap();
        assert_eq!(t, rt);
        assert_eq!(rt.cell(1, 0), Scalar::Null);
        assert_eq!(rt.cell(0, 1), Scalar::Utf8("aa".into()));
    }

    #[test]
    fn empty_table_roundtrip() {
        let t = sample().slice(0, 0);
        let rt = deserialize(&serialize(&t)).unwrap();
        assert_eq!(rt.num_rows(), 0);
        assert_eq!(rt.num_columns(), 4);
    }

    #[test]
    fn corrupt_inputs_rejected() {
        assert!(deserialize(b"nope").is_err());
        let mut bytes = serialize(&sample());
        bytes.truncate(bytes.len() - 3);
        assert!(deserialize(&bytes).is_err());
        let mut extra = serialize(&sample());
        extra.push(0);
        assert!(deserialize(&extra).is_err());
    }
}
