//! Human-readable table rendering for the CLI and examples.

use super::table::Table;
use std::fmt::Write as _;

/// Render up to `max_rows` rows as an aligned ASCII grid.
pub fn pretty(table: &Table, max_rows: usize) -> String {
    let ncols = table.num_columns();
    let shown = table.num_rows().min(max_rows);
    let mut cells: Vec<Vec<String>> = Vec::with_capacity(shown + 1);
    cells.push(
        table
            .schema()
            .fields()
            .iter()
            .map(|f| format!("{} ({})", f.name, f.data_type))
            .collect(),
    );
    for r in 0..shown {
        cells.push((0..ncols).map(|c| table.cell(r, c).to_string()).collect());
    }
    let mut widths = vec![0usize; ncols];
    for row in &cells {
        for (c, s) in row.iter().enumerate() {
            widths[c] = widths[c].max(s.len());
        }
    }
    let mut out = String::new();
    for (i, row) in cells.iter().enumerate() {
        for (c, s) in row.iter().enumerate() {
            let _ = write!(out, "| {:w$} ", s, w = widths[c]);
        }
        out.push_str("|\n");
        if i == 0 {
            for &w in &widths {
                let _ = write!(out, "+{}", "-".repeat(w + 2));
            }
            out.push_str("+\n");
        }
    }
    if table.num_rows() > shown {
        let _ = writeln!(out, "... {} more rows", table.num_rows() - shown);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::table::array::Array;

    #[test]
    fn renders_and_truncates() {
        let t = Table::from_columns(vec![
            ("id", Array::from_i64(vec![1, 2, 3])),
            ("name", Array::from_opt_strs(vec![Some("long-name"), None, Some("x")])),
        ])
        .unwrap();
        let s = pretty(&t, 2);
        assert!(s.contains("id (int64)"));
        assert!(s.contains("long-name"));
        assert!(s.contains("null"));
        assert!(s.contains("1 more rows"));
    }
}
