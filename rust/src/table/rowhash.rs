//! Row hashing and row equality over key columns.
//!
//! These are the shared primitives under hash join, group-by, unique,
//! isin and hash-partitioned shuffle — the paper's Table 5 compositions
//! all bottom out here. Hashes are computed column-at-a-time
//! (vectorised) and combined per row, so the hot loop never branches on
//! data type per cell.
//!
//! Mapping hashes to destination partitions is deliberately NOT here:
//! that is a routing decision, owned by `crate::comm::partitioner`
//! (DESIGN.md §5) so batch shuffle and streaming keyed edges cannot
//! drift apart.

use super::array::Array;

/// 64-bit finaliser (splitmix64). Good avalanche, cheap.
#[inline]
pub fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

/// Combine a new column hash into a running row hash.
#[inline]
fn combine(acc: u64, h: u64) -> u64 {
    // boost-style hash_combine, widened to 64-bit.
    acc ^ (h
        .wrapping_add(0x9E3779B97F4A7C15)
        .wrapping_add(acc << 6)
        .wrapping_add(acc >> 2))
}

const NULL_HASH: u64 = 0xA5A5_5A5A_DEAD_BEEF;

/// Hash one string.
#[inline]
fn hash_bytes(b: &[u8]) -> u64 {
    // FNV-1a with a splitmix finaliser: fast on the short keys the
    // UNOMT pipeline produces (drug ids, cell-line names).
    let mut h: u64 = 0xcbf29ce484222325;
    for &byte in b {
        h ^= byte as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    mix64(h)
}

/// Canonical bits for f64 so that `-0.0 == 0.0` and all NaNs collide.
#[inline]
fn canon_f64(v: f64) -> u64 {
    if v.is_nan() {
        0x7FF8_0000_0000_0000
    } else if v == 0.0 {
        0
    } else {
        v.to_bits()
    }
}

/// Per-column hashes, written into (or combined with) `out`.
fn hash_column_into(col: &Array, out: &mut [u64], first: bool) {
    let n = col.len();
    debug_assert_eq!(out.len(), n);
    macro_rules! body {
        ($get:expr) => {
            for i in 0..n {
                let h = if col.is_valid(i) { $get(i) } else { NULL_HASH };
                out[i] = if first { h } else { combine(out[i], h) };
            }
        };
    }
    match col {
        Array::Int64(v, _) => body!(|i: usize| mix64(v[i] as u64)),
        // Timestamps hash like an Int64 of the same ms value — key
        // columns never mix the two types, so no cross-type collisions.
        Array::Timestamp(v, _) => body!(|i: usize| mix64(v[i] as u64)),
        Array::Float64(v, _) => body!(|i: usize| mix64(canon_f64(v[i]))),
        Array::Bool(v, _) => body!(|i: usize| mix64(v[i] as u64 + 1)),
        Array::Utf8(d, _) => body!(|i: usize| hash_bytes(
            &d.bytes[d.offsets[i] as usize..d.offsets[i + 1] as usize]
        )),
        Array::DictUtf8(d, _) => {
            // Hash each distinct value once, then fan out through the
            // codes: O(dict bytes + rows) instead of O(total bytes).
            // Entry hashes use `hash_bytes`, so a dictionary-encoded
            // column hashes identically to its plain twin — shuffle
            // routing cannot depend on physical encoding.
            let entry_hash: Vec<u64> =
                d.dict.iter().map(|s| hash_bytes(s.as_bytes())).collect();
            body!(|i: usize| entry_hash[d.codes[i] as usize])
        }
    }
}

/// Row hashes over a set of key columns (all must share a length).
pub fn hash_columns(cols: &[&Array]) -> Vec<u64> {
    assert!(!cols.is_empty(), "hash_columns: no key columns");
    let n = cols[0].len();
    let mut out = vec![0u64; n];
    for (k, col) in cols.iter().enumerate() {
        assert_eq!(col.len(), n, "key column length mismatch");
        hash_column_into(col, &mut out, k == 0);
    }
    out
}

/// Total order on f64 consistent with [`cell_eq`]'s canonicalisation:
/// `-0.0 == 0.0`, all NaNs equal and greater than every number.
#[inline]
pub fn canonical_f64_total_cmp(a: f64, b: f64) -> std::cmp::Ordering {
    use std::cmp::Ordering;
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
        (false, false) => a.partial_cmp(&b).unwrap(),
    }
}

/// Cell equality between `a[i]` and `b[j]` with null == null semantics
/// (group-by / unique semantics; SQL joins filter nulls before probing).
#[inline]
pub fn cell_eq(a: &Array, i: usize, b: &Array, j: usize) -> bool {
    match (a.is_valid(i), b.is_valid(j)) {
        (false, false) => true,
        (true, true) => match (a, b) {
            (Array::Int64(x, _), Array::Int64(y, _)) => x[i] == y[j],
            (Array::Float64(x, _), Array::Float64(y, _)) => canon_f64(x[i]) == canon_f64(y[j]),
            (Array::Bool(x, _), Array::Bool(y, _)) => x[i] == y[j],
            (Array::Utf8(x, _), Array::Utf8(y, _)) => x.value(i) == y.value(j),
            (Array::DictUtf8(x, _), Array::DictUtf8(y, _)) => {
                // Same dictionary instance (the group-by/unique probe
                // case: both sides of the comparison are one column) →
                // compare u32 codes; otherwise fall back to the strings.
                if std::ptr::eq(x, y) {
                    x.codes[i] == y.codes[j]
                } else {
                    x.value(i) == y.value(j)
                }
            }
            (Array::DictUtf8(x, _), Array::Utf8(y, _)) => x.value(i) == y.value(j),
            (Array::Utf8(x, _), Array::DictUtf8(y, _)) => x.value(i) == y.value(j),
            (Array::Timestamp(x, _), Array::Timestamp(y, _)) => x[i] == y[j],
            _ => false,
        },
        _ => false,
    }
}

/// Row equality across parallel key-column sets.
#[inline]
pub fn rows_eq(left: &[&Array], i: usize, right: &[&Array], j: usize) -> bool {
    left.iter()
        .zip(right.iter())
        .all(|(a, b)| cell_eq(a, i, b, j))
}

/// True when any key cell in row `i` is null (SQL join semantics: null
/// keys never match).
#[inline]
pub fn any_null(cols: &[&Array], i: usize) -> bool {
    cols.iter().any(|c| c.is_null(i))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_rows_hash_equal() {
        let a = Array::from_i64(vec![1, 2, 1]);
        let b = Array::from_strs(&["x", "y", "x"]);
        let h = hash_columns(&[&a, &b]);
        assert_eq!(h[0], h[2]);
        assert_ne!(h[0], h[1]);
    }

    #[test]
    fn null_handling() {
        let a = Array::from_opt_i64(vec![None, None, Some(0)]);
        let h = hash_columns(&[&a]);
        assert_eq!(h[0], h[1]);
        assert_ne!(h[0], h[2]);
        assert!(cell_eq(&a, 0, &a, 1));
        assert!(!cell_eq(&a, 0, &a, 2));
        assert!(any_null(&[&a], 0));
        assert!(!any_null(&[&a], 2));
    }

    #[test]
    fn float_canonicalisation() {
        let a = Array::from_f64(vec![0.0, -0.0, f64::NAN, f64::NAN]);
        let h = hash_columns(&[&a]);
        assert_eq!(h[0], h[1]);
        assert_eq!(h[2], h[3]);
        assert!(cell_eq(&a, 2, &a, 3));
        assert!(cell_eq(&a, 0, &a, 1));
    }

    #[test]
    fn cross_table_row_eq() {
        let a1 = Array::from_i64(vec![1, 2]);
        let b1 = Array::from_strs(&["u", "v"]);
        let a2 = Array::from_i64(vec![2]);
        let b2 = Array::from_strs(&["v"]);
        assert!(rows_eq(&[&a1, &b1], 1, &[&a2, &b2], 0));
        assert!(!rows_eq(&[&a1, &b1], 0, &[&a2, &b2], 0));
    }

    #[test]
    fn dict_hashes_identically_to_plain() {
        // Routing invariance: the hash of a value must not depend on
        // its physical encoding, or shuffles would place the same key
        // on different ranks for dict vs plain inputs.
        let plain = Array::from_opt_strs(vec![Some("aa"), None, Some("bb"), Some("aa")]);
        let dict = plain.clone().dict_encode();
        assert_eq!(hash_columns(&[&plain]), hash_columns(&[&dict]));
    }

    #[test]
    fn dict_cell_eq_same_array_and_mixed() {
        let plain = Array::from_opt_strs(vec![Some("x"), Some("y"), None, Some("x")]);
        let dict = plain.clone().dict_encode();
        // same-array probe (code fast path)
        assert!(cell_eq(&dict, 0, &dict, 3));
        assert!(!cell_eq(&dict, 0, &dict, 1));
        assert!(cell_eq(&dict, 2, &dict, 2), "null == null");
        // mixed encodings compare by value
        assert!(cell_eq(&dict, 0, &plain, 0));
        assert!(cell_eq(&plain, 1, &dict, 1));
        assert!(!cell_eq(&plain, 0, &dict, 1));
        // two distinct dictionaries compare by value
        let other = Array::dict_from_strs(&["y", "x"]);
        assert!(cell_eq(&dict, 0, &other, 1));
        assert!(!cell_eq(&dict, 0, &other, 0));
    }
}
