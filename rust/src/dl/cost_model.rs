//! Accelerator cost model for the Fig 17 reproduction.
//!
//! The paper's GPU experiment (Tesla K80 + NCCL) is hardware we do not
//! have; per DESIGN.md §3 we reproduce its *shape* with a calibrated
//! model over measured CPU quantities:
//!
//! * device compute = measured CPU compute / `compute_speedup`
//!   (the paper reports "the speed-up from GPUs is 2x compared to CPUs
//!   in this network");
//! * gradient allreduce = NCCL ring over the accelerator link profile:
//!   2(W-1)/W × bytes at link bandwidth + 2(W-1) launch latencies;
//! * the paper's observation "execution time was dominated by the
//!   communication time" falls out of the ratio.

use crate::comm::profile::LinkProfile;

/// Calibrated accelerator profile.
#[derive(Debug, Clone, Copy)]
pub struct AccelProfile {
    /// Device compute speedup over CPU for this network (paper: ~2x).
    pub compute_speedup: f64,
    /// Device interconnect.
    pub link: LinkProfile,
}

impl Default for AccelProfile {
    fn default() -> Self {
        AccelProfile { compute_speedup: 2.0, link: LinkProfile::accelerator() }
    }
}

/// Modeled per-step time breakdown on the accelerator.
#[derive(Debug, Clone, Copy)]
pub struct AccelStep {
    pub compute_seconds: f64,
    pub comm_seconds: f64,
}

impl AccelStep {
    pub fn total(&self) -> f64 {
        self.compute_seconds + self.comm_seconds
    }

    pub fn comm_fraction(&self) -> f64 {
        self.comm_seconds / self.total()
    }
}

/// Model one DDP step on `world` devices.
///
/// `cpu_compute_seconds` is the measured per-step CPU compute on ONE
/// rank (grad_step + apply_step); `grad_bytes` the flat gradient size.
pub fn model_step(
    p: &AccelProfile,
    world: usize,
    cpu_compute_seconds: f64,
    grad_bytes: usize,
) -> AccelStep {
    let compute = cpu_compute_seconds / p.compute_speedup;
    let comm = if world <= 1 {
        0.0
    } else {
        // Ring allreduce: 2(W-1) steps, each moving bytes/W per device.
        let steps = 2 * (world - 1);
        let per_step_bytes = grad_bytes as f64 / world as f64;
        steps as f64 * (p.link.intra.latency + per_step_bytes / p.link.intra.bandwidth)
    };
    AccelStep { compute_seconds: compute, comm_seconds: comm }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_device_has_no_comm() {
        let s = model_step(&AccelProfile::default(), 1, 0.1, 1 << 20);
        assert_eq!(s.comm_seconds, 0.0);
        assert_eq!(s.compute_seconds, 0.05); // 2x speedup
    }

    #[test]
    fn comm_grows_with_world_then_saturates() {
        let p = AccelProfile::default();
        let g = 4 << 20; // 4 MiB of gradients
        let c2 = model_step(&p, 2, 0.1, g).comm_seconds;
        let c4 = model_step(&p, 4, 0.1, g).comm_seconds;
        let c8 = model_step(&p, 8, 0.1, g).comm_seconds;
        assert!(c4 > c2);
        // ring volume approaches 2*bytes as W grows: c8/c4 < 2
        assert!(c8 / c4 < 1.6, "c8={c8} c4={c4}");
    }

    #[test]
    fn paper_shape_comm_dominated_at_scale() {
        // The paper strong-scales: the global batch is fixed, so
        // per-device compute shrinks ~1/W while the allreduce volume is
        // constant — "execution time was dominated by the communication
        // time". Network ≈ 5.6M params (f32 ≈ 22 MiB grads), full-batch
        // CPU step ≈ 60 ms.
        let p = AccelProfile::default();
        let cpu_full_batch = 0.060;
        let w = 8;
        let s = model_step(&p, w, cpu_full_batch / w as f64, 22 << 20);
        assert!(s.comm_fraction() > 0.5, "comm fraction {}", s.comm_fraction());
        // ...while a single device is compute-only.
        let s1 = model_step(&p, 1, cpu_full_batch, 22 << 20);
        assert_eq!(s1.comm_fraction(), 0.0);
    }
}
