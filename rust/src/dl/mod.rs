//! Deep-learning layer: DDP training over the AOT-compiled UNOMT model
//! (the paper's stage 3–4: tensors from engineered features, then
//! distributed data-parallel training).

pub mod cost_model;
pub mod dataloader;
pub mod trainer;

pub use cost_model::{model_step, AccelProfile, AccelStep};
pub use dataloader::Dataset;
pub use trainer::{synthetic_dataset, train_ddp, TrainConfig, TrainReport};
