//! Mini-batch loader: fixed-size batches from in-memory feature/label
//! buffers (stage 3→4 of the paper's workflow: engineered features →
//! tensors → training batches).

use anyhow::{bail, Result};

/// In-memory dataset: row-major features (n, d_in) + labels (n, 1).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub x: Vec<f32>,
    pub y: Vec<f32>,
    pub n: usize,
    pub d_in: usize,
}

impl Dataset {
    pub fn new(x: Vec<f32>, y: Vec<f32>, d_in: usize) -> Result<Dataset> {
        if d_in == 0 || x.len() % d_in != 0 {
            bail!("dataset: x length {} not divisible by d_in {d_in}", x.len());
        }
        let n = x.len() / d_in;
        if y.len() != n {
            bail!("dataset: {} labels for {n} rows", y.len());
        }
        Ok(Dataset { x, y, n, d_in })
    }

    /// From an f64 row-major feature matrix whose LAST column is the
    /// label (the UNOMT convention: features + growth).
    pub fn from_row_major_with_label(buf: &[f64], nrows: usize, ncols: usize) -> Result<Dataset> {
        if ncols < 2 {
            bail!("need at least one feature and the label column");
        }
        let d_in = ncols - 1;
        let mut x = Vec::with_capacity(nrows * d_in);
        let mut y = Vec::with_capacity(nrows);
        for r in 0..nrows {
            for c in 0..d_in {
                x.push(buf[r * ncols + c] as f32);
            }
            y.push(buf[r * ncols + d_in] as f32);
        }
        Dataset::new(x, y, d_in)
    }

    /// Number of full batches of `batch` rows (remainder dropped, as
    /// the AOT batch dim is static).
    pub fn num_batches(&self, batch: usize) -> usize {
        self.n / batch
    }

    /// Borrow batch `b` as (x_slice, y_slice).
    pub fn batch(&self, b: usize, batch: usize) -> (&[f32], &[f32]) {
        let lo = b * batch;
        (&self.x[lo * self.d_in..(lo + batch) * self.d_in], &self.y[lo..lo + batch])
    }

    /// Pad with row repeats so n is a multiple of `batch` (used when a
    /// rank's shard is smaller than one batch).
    pub fn pad_to_multiple(&mut self, batch: usize) {
        if self.n == 0 || self.n % batch == 0 {
            return;
        }
        let target = self.n.div_ceil(batch) * batch;
        let mut r = 0;
        while self.n < target {
            let lo = r * self.d_in;
            let row: Vec<f32> = self.x[lo..lo + self.d_in].to_vec();
            self.x.extend_from_slice(&row);
            self.y.push(self.y[r]);
            self.n += 1;
            r = (r + 1) % self.n.min(self.n - 1).max(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_and_bounds() {
        let d = Dataset::new((0..20).map(|i| i as f32).collect(), (0..10).map(|i| i as f32).collect(), 2)
            .unwrap();
        assert_eq!(d.n, 10);
        assert_eq!(d.num_batches(4), 2);
        let (x, y) = d.batch(1, 4);
        assert_eq!(x.len(), 8);
        assert_eq!(y, &[4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn from_row_major_splits_label() {
        // 2 rows, 3 cols: features 2 + label
        let buf = vec![1.0, 2.0, 10.0, 3.0, 4.0, 20.0];
        let d = Dataset::from_row_major_with_label(&buf, 2, 3).unwrap();
        assert_eq!(d.d_in, 2);
        assert_eq!(d.x, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(d.y, vec![10.0, 20.0]);
    }

    #[test]
    fn padding() {
        let mut d = Dataset::new(vec![1.0, 2.0, 3.0], vec![9.0, 8.0, 7.0], 1).unwrap();
        d.pad_to_multiple(2);
        assert_eq!(d.n, 4);
        assert_eq!(d.num_batches(2), 2);
    }

    #[test]
    fn validation() {
        assert!(Dataset::new(vec![1.0; 3], vec![1.0], 2).is_err());
        assert!(Dataset::new(vec![1.0; 4], vec![1.0], 2).is_err());
    }
}
