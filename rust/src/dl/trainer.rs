//! Distributed-data-parallel trainer: the paper's stage 4 (PyTorch-DDP
//! role) implemented in Rust over PJRT + the HPTMT communicator.
//!
//! Per step, every rank:
//! 1. executes the AOT `grad_step` on its local mini-batch (PJRT),
//! 2. ring-allreduces the flat gradient (the NCCL/MPI role),
//! 3. executes `apply_step` with the averaged gradient.
//!
//! Because every rank starts from identical parameters and applies
//! identical averaged gradients, parameters stay replicated — the same
//! invariant PyTorch DDP maintains. The BSP character is explicit: the
//! only synchronisation is the allreduce.

use super::dataloader::Dataset;
use crate::comm::collectives::{allreduce_f32, allreduce_sum_f64};
use crate::comm::{Communicator, ReduceOp};
use crate::runtime::{flatten, unflatten, ModelRuntime};
use crate::util::time::CpuStopwatch;
use anyhow::{bail, Result};

/// Training configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub artifacts_dir: String,
    pub lr: f32,
    pub steps: usize,
    /// Log the (allreduced) loss every N steps; 0 = never.
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { artifacts_dir: "artifacts".into(), lr: 0.01, steps: 100, log_every: 10 }
    }
}

/// Per-rank training report.
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// Allreduced mean loss per step.
    pub losses: Vec<f32>,
    /// CPU seconds in grad_step + apply_step (compute).
    pub compute_seconds: f64,
    /// CPU seconds inside allreduce calls (serialisation etc.).
    pub comm_cpu_seconds: f64,
    /// Modeled wire seconds (from the communicator's link profile).
    pub comm_sim_seconds: f64,
    /// Gradient bytes allreduced per step.
    pub grad_bytes_per_step: usize,
    pub steps: usize,
}

/// Run DDP training on this rank's shard. All ranks must call with the
/// same config and a consistent runtime (same artifacts).
pub fn train_ddp<C: Communicator + ?Sized>(
    comm: &mut C,
    runtime: &ModelRuntime,
    shard: &Dataset,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    let dims = &runtime.manifest.dims;
    if shard.d_in != dims.d_in {
        bail!("shard d_in {} != model d_in {}", shard.d_in, dims.d_in);
    }
    let batch = dims.batch;
    let nb = shard.num_batches(batch);
    if nb == 0 {
        bail!("shard has {} rows < one batch of {batch}", shard.n);
    }
    let world = comm.world_size() as f32;

    let mut params = runtime.init_params()?;
    let mut losses = Vec::with_capacity(cfg.steps);
    let mut compute = 0.0f64;
    let mut comm_cpu = 0.0f64;
    let sim0 = comm.stats().sim_comm_seconds;
    let mut grad_bytes = 0usize;

    for step in 0..cfg.steps {
        let b = step % nb;
        let (x, y) = shard.batch(b, batch);
        // Distinct dropout mask per (rank, step).
        let seed = (step * comm.world_size() + comm.rank()) as i32;

        let sw = CpuStopwatch::start();
        let (loss, grads) = runtime.grad_step(&params, x, y, seed)?;
        compute += sw.elapsed().as_secs_f64();

        // Allreduce the flat gradient; average by 1/W.
        let flat = flatten(&grads);
        grad_bytes = flat.len() * 4;
        let sw = CpuStopwatch::start();
        let mut summed = allreduce_f32(comm, &flat, ReduceOp::Sum)?;
        comm_cpu += sw.elapsed().as_secs_f64();
        for g in summed.iter_mut() {
            *g /= world;
        }
        let avg = unflatten(&summed, &runtime.manifest)?;

        let sw = CpuStopwatch::start();
        params = runtime.apply_step(&params, &avg, cfg.lr)?;
        compute += sw.elapsed().as_secs_f64();

        // Mean loss across ranks for the logged curve.
        let sw = CpuStopwatch::start();
        let mean_loss = (allreduce_sum_f64(comm, loss as f64)? / world as f64) as f32;
        comm_cpu += sw.elapsed().as_secs_f64();
        losses.push(mean_loss);

        if cfg.log_every > 0 && step % cfg.log_every == 0 && comm.rank() == 0 {
            println!("step {step:>5}  loss {mean_loss:.6}");
        }
    }

    Ok(TrainReport {
        losses,
        compute_seconds: compute,
        comm_cpu_seconds: comm_cpu,
        comm_sim_seconds: comm.stats().sim_comm_seconds - sim0,
        grad_bytes_per_step: grad_bytes,
        steps: cfg.steps,
    })
}

/// Synthetic learnable drug-response-like dataset for tests/benches:
/// features ~ N(0,1), label = linear(features)*0.5 + noise.
pub fn synthetic_dataset(n: usize, d_in: usize, seed: u64) -> Dataset {
    let mut rng = crate::util::rng::Rng::new(seed);
    let w: Vec<f32> = (0..d_in).map(|_| rng.normal() as f32).collect();
    let mut x = Vec::with_capacity(n * d_in);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let mut dot = 0.0f32;
        for &wi in &w {
            let xi = rng.normal() as f32;
            x.push(xi);
            dot += wi * xi;
        }
        y.push(0.5 * dot / (d_in as f32).sqrt() + 0.01 * rng.normal() as f32);
    }
    Dataset::new(x, y, d_in).expect("consistent synthetic dataset")
}
