//! One rank of a multiprocess world (`HPTMT_COMM=process`).
//!
//! Spawned by `comm::launch::Launcher`, never run by hand. Reads its
//! identity and task from the environment, joins the socket mesh, runs
//! the named job, writes its result to `out-{rank}.bin` in the
//! rendezvous directory, and barriers so no rank exits before every
//! result is durable.

use anyhow::{Context, Result};
use hptmt::comm::{run_job, Communicator, ProcComm, ProfileSpec};
use hptmt::obs;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn env(name: &str) -> Result<String> {
    std::env::var(name).with_context(|| format!("{name} must be set (spawned by the launcher)"))
}

fn main() -> Result<()> {
    let rank: usize = env("HPTMT_RANK")?.parse().context("HPTMT_RANK")?;
    let world: usize = env("HPTMT_WORLD")?.parse().context("HPTMT_WORLD")?;
    let dir = PathBuf::from(env("HPTMT_COMM_DIR")?);
    let job = env("HPTMT_JOB")?;
    let arg = std::env::var("HPTMT_JOB_ARG").unwrap_or_default();
    let profile = ProfileSpec::parse(
        &std::env::var("HPTMT_LINK_PROFILE").unwrap_or_default(),
    )?
    .profile();
    let timeout = std::env::var("HPTMT_COMM_TIMEOUT_SECS")
        .ok()
        .and_then(|s| s.parse().ok())
        .map(Duration::from_secs)
        .unwrap_or(Duration::from_secs(30));

    // This process IS a rank: job code must not re-enter the launcher,
    // whatever HPTMT_COMM says in the inherited environment.
    std::env::remove_var("HPTMT_COMM");

    // Per-rank observability scope (the process-backend counterpart of
    // what `spawn_world` installs on rank threads). `HPTMT_TRACE` is
    // inherited from the launcher's environment, so tracing a
    // multiprocess world needs no extra plumbing.
    let rank_obs = Arc::new(obs::RankObs::for_rank(rank));
    let _obs_scope = obs::install_scope(rank_obs.clone());

    let mut comm = ProcComm::connect_with(rank, world, &dir, profile, timeout)
        .with_context(|| format!("rank {rank}/{world} joining the mesh at {}", dir.display()))?;
    let out = run_job(&job, &arg, &mut comm)
        .with_context(|| format!("rank {rank}/{world} running job {job:?}"))?;
    std::fs::write(dir.join(format!("out-{rank}.bin")), &out)
        .with_context(|| format!("rank {rank} writing result"))?;

    // Export this rank's trace next to its result when an exporter
    // format was requested (deterministic fields + timing per span).
    let trace_mode = obs::trace::mode();
    if matches!(trace_mode, obs::TraceMode::Chrome | obs::TraceMode::Jsonl) {
        obs::trace::flush_thread_events();
        let events = rank_obs.take_events();
        let (name, body) = match trace_mode {
            obs::TraceMode::Chrome => (
                format!("trace-{rank}.json"),
                obs::trace::export_chrome(rank, &events),
            ),
            _ => (
                format!("trace-{rank}.jsonl"),
                obs::trace::export_jsonl(rank, &events),
            ),
        };
        std::fs::write(dir.join(name), body)
            .with_context(|| format!("rank {rank} writing trace"))?;
    }
    // Everyone's result is on disk before anyone tears down its socket.
    comm.barrier()?;
    Ok(())
}
