//! `bench_diff` — compare two `bench::Report` JSON files and flag
//! per-row regressions beyond a threshold (the BENCH_*.json trajectory
//! tool from the ROADMAP).
//!
//! ```bash
//! # current run vs the checked-in baseline from the previous PR
//! cargo run --bin bench_diff -- bench_out/fig13_parallel_pipeline.json \
//!     BENCH_fig13_parallel_pipeline.json --threshold 1.15
//! ```
//!
//! Rows are matched by their first cell (the series/x column). Numeric
//! cells are compared as `new / old`; a ratio above the threshold is a
//! regression, below its inverse an improvement. Metrics are assumed
//! cost-like (seconds — bigger is worse), matching every `bench::Report`
//! this crate emits. Columns named in `--strict-cols a,b` are exempt
//! from that asymmetry: they hold deterministic counts (emitted
//! windows, groups) where *any* change — including a drop the ratio
//! rule would praise as "improved" — is a failure. Exits non-zero when
//! any regression is found, so CI can gate on it. Files recorded at
//! different `HPTMT_BENCH_SCALE`s are refused: their row counts are
//! not comparable.

//! Exit codes: `0` clean, `1` regressions/missing rows, `2` baseline
//! file missing or unreadable (actionable: seed it from the fresh run),
//! `3` report-name mismatch (comparing unrelated trajectories).

use anyhow::{bail, Context, Result};
use hptmt::util::cli::Args;
use hptmt::util::json::Json;
use std::collections::BTreeMap;

const EXIT_REGRESSION: i32 = 1;
const EXIT_MISSING_BASELINE: i32 = 2;
const EXIT_NAME_MISMATCH: i32 = 3;

/// Actionable message for a baseline that cannot be loaded: say what
/// was tried, why it matters, and the exact command that seeds it.
fn missing_baseline_message(path: &str, err: &anyhow::Error) -> String {
    format!(
        "bench_diff: baseline {path} is missing or unreadable ({err:#}).\n\
         A trajectory gate needs the previous PR's report checked in. Seed it from the\n\
         fresh run and commit it:\n\
         \n    cp bench_out/<name>.json {path}\n\
         \nthen re-run bench_diff. (exit {EXIT_MISSING_BASELINE})"
    )
}

/// Actionable message for comparing two different benchmarks.
fn name_mismatch_message(new_name: &str, base_name: &str) -> String {
    format!(
        "bench_diff: report name mismatch: new run is {new_name:?} but baseline is\n\
         {base_name:?} — these are different trajectories and their rows are not\n\
         comparable. Pass the baseline recorded for {new_name:?} (or rebaseline with\n\
         the fresh report). (exit {EXIT_NAME_MISMATCH})"
    )
}

/// One parsed report: name, scale, header, rows keyed by first cell.
struct ReportFile {
    name: String,
    scale: f64,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

fn load(path: &str) -> Result<ReportFile> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let j = Json::parse(&text).with_context(|| format!("parsing {path}"))?;
    let strs = |key: &str| -> Result<Vec<String>> {
        Ok(j.get(key)?
            .as_arr()?
            .iter()
            .map(|c| c.as_str().map(str::to_string))
            .collect::<Result<_>>()?)
    };
    let rows = j
        .get("rows")?
        .as_arr()?
        .iter()
        .map(|r| {
            r.as_arr()?
                .iter()
                .map(|c| c.as_str().map(str::to_string))
                .collect::<Result<Vec<String>>>()
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(ReportFile {
        name: j.get("name")?.as_str()?.to_string(),
        scale: j.get("scale")?.as_f64()?,
        header: strs("header")?,
        rows,
    })
}

/// Parse a report cell as a number, tolerating unit-ish suffixes the
/// reports use ("1.23x", "45%", "0.5s").
fn parse_numeric(cell: &str) -> Option<f64> {
    let t = cell.trim();
    if let Ok(v) = t.parse::<f64>() {
        return Some(v);
    }
    let stripped = t.trim_end_matches(|c: char| c.is_alphabetic() || c == '%');
    if stripped.len() < t.len() {
        stripped.parse::<f64>().ok()
    } else {
        None
    }
}

fn main() -> Result<()> {
    let args = Args::from_env(0);
    let [new_path, base_path] = args.positional() else {
        bail!(
            "usage: bench_diff <bench_out/NAME.json> <BENCH_NAME.json> \
             [--threshold 1.10] [--strict-cols windows,groups]"
        );
    };
    let threshold = args.f64_or("threshold", 1.10)?;
    if threshold <= 1.0 {
        bail!("--threshold must be > 1.0, got {threshold}");
    }
    let strict_cols: Vec<String> = args
        .get("strict-cols")
        .map(|s| s.split(',').map(|c| c.trim().to_string()).filter(|c| !c.is_empty()).collect())
        .unwrap_or_default();

    let new = load(new_path)?;
    let base = match load(base_path) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("{}", missing_baseline_message(base_path, &e));
            std::process::exit(EXIT_MISSING_BASELINE);
        }
    };
    if new.name != base.name {
        eprintln!("{}", name_mismatch_message(&new.name, &base.name));
        std::process::exit(EXIT_NAME_MISMATCH);
    }
    if new.scale != base.scale {
        bail!(
            "scale mismatch: {} vs {} — runs at different HPTMT_BENCH_SCALE are not comparable",
            new.scale,
            base.scale
        );
    }
    if new.header != base.header {
        bail!("header mismatch: {:?} vs {:?} — schema changed, rebaseline", new.header, base.header);
    }

    let key_of = |row: &[String]| row.first().cloned().unwrap_or_default();
    let base_rows: BTreeMap<String, &Vec<String>> =
        base.rows.iter().map(|r| (key_of(r), r)).collect();

    println!(
        "== bench_diff {} (threshold {threshold:.2}x, scale {}) ==",
        new.name, new.scale
    );
    let mut regressions = 0usize;
    let mut compared = 0usize;
    for row in &new.rows {
        let key = key_of(row);
        let Some(old) = base_rows.get(&key) else {
            println!("  {key:<24} NEW ROW (no baseline)");
            continue;
        };
        for (c, col) in new.header.iter().enumerate().skip(1) {
            if strict_cols.iter().any(|s| s == col) {
                // Deterministic cell: any change is a failure — drops
                // included (fewer emitted windows is lost coverage, not
                // an improvement), and so is a cell that went missing
                // or stopped parsing as the baseline's text.
                compared += 1;
                let (nv, ov) = (row.get(c), old.get(c));
                let flag = match (nv, ov) {
                    (Some(n), Some(o)) if n == o => "ok",
                    _ => {
                        regressions += 1;
                        "CHANGED (strict)"
                    }
                };
                println!(
                    "  {key:<24} {col:<16} {:>12} -> {:>12}  {:>7}  {flag}",
                    ov.map_or("<missing>", String::as_str),
                    nv.map_or("<missing>", String::as_str),
                    "exact"
                );
                continue;
            }
            let (Some(n), Some(o)) = (
                row.get(c).and_then(|s| parse_numeric(s)),
                old.get(c).and_then(|s| parse_numeric(s)),
            ) else {
                continue; // non-numeric cell (labels, notes)
            };
            compared += 1;
            if o <= 0.0 {
                continue; // zero/negative baselines have no meaningful ratio
            }
            let ratio = n / o;
            let flag = if ratio > threshold {
                regressions += 1;
                "REGRESSION"
            } else if ratio < 1.0 / threshold {
                "improved"
            } else {
                "ok"
            };
            println!("  {key:<24} {col:<16} {o:>12.4} -> {n:>12.4}  {ratio:>6.2}x  {flag}");
        }
    }
    // Baseline rows that vanished from the new run are coverage loss,
    // not a pass: count them as failures so a renamed/dropped series
    // cannot silently bypass the gate. (New rows are fine — they gain
    // a baseline when BENCH_*.json is next refreshed.)
    let mut missing = 0usize;
    for row in &base.rows {
        let key = key_of(row);
        if !new.rows.iter().any(|r| key_of(r) == key) {
            missing += 1;
            println!("  {key:<24} MISSING (present in baseline only)");
        }
    }
    if compared == 0 && !base.rows.is_empty() {
        bail!("no numeric cells compared against a non-empty baseline — nothing was checked");
    }
    if regressions > 0 || missing > 0 {
        println!("{regressions} regression(s) beyond {threshold:.2}x, {missing} missing row(s)");
        std::process::exit(EXIT_REGRESSION);
    }
    println!("no regressions beyond {threshold:.2}x");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_baseline_is_actionable_not_a_panic() {
        let path = std::env::temp_dir().join("bench-diff-test-does-not-exist.json");
        let path = path.to_string_lossy().into_owned();
        let err = load(&path).expect_err("loading a missing baseline must be an Err");
        let msg = missing_baseline_message(&path, &err);
        assert!(msg.contains(&path), "message must name the missing file");
        assert!(msg.contains("cp bench_out/"), "message must say how to seed the baseline");
        assert!(msg.contains("exit 2"), "message must carry the distinct exit code");
    }

    #[test]
    fn unparseable_baseline_is_an_error_not_a_panic() {
        let path = std::env::temp_dir().join(format!("bench-diff-garbage-{}.json", std::process::id()));
        std::fs::write(&path, b"{not json!").unwrap();
        let res = load(&path.to_string_lossy());
        std::fs::remove_file(&path).unwrap();
        assert!(res.is_err(), "garbage JSON must surface as Err, not panic");
    }

    #[test]
    fn name_mismatch_names_both_trajectories() {
        let msg = name_mismatch_message("fig13_keyed_windowed", "fig4_dist_join");
        assert!(msg.contains("fig13_keyed_windowed"));
        assert!(msg.contains("fig4_dist_join"));
        assert!(msg.contains("not"), "message must say the rows are not comparable");
        assert!(msg.contains("exit 3"), "message must carry the distinct exit code");
    }

    #[test]
    fn load_reads_a_well_formed_report() {
        let path = std::env::temp_dir().join(format!("bench-diff-ok-{}.json", std::process::id()));
        std::fs::write(
            &path,
            br#"{"name":"t","scale":1.0,"header":["x","cpu_s"],"rows":[["1","0.5"]]}"#,
        )
        .unwrap();
        let rep = load(&path.to_string_lossy()).unwrap();
        std::fs::remove_file(&path).unwrap();
        assert_eq!(rep.name, "t");
        assert_eq!(rep.header, vec!["x", "cpu_s"]);
        assert_eq!(rep.rows, vec![vec!["1".to_string(), "0.5".to_string()]]);
    }
}
