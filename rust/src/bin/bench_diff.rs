//! `bench_diff` — compare two `bench::Report` JSON files and flag
//! per-row regressions beyond a threshold (the BENCH_*.json trajectory
//! tool from the ROADMAP).
//!
//! ```bash
//! # current run vs the checked-in baseline from the previous PR
//! cargo run --bin bench_diff -- bench_out/fig13_parallel_pipeline.json \
//!     BENCH_fig13_parallel_pipeline.json --threshold 1.15
//! ```
//!
//! Rows are matched by their first cell (the series/x column). Numeric
//! cells are compared as `new / old`; a ratio above the threshold is a
//! regression, below its inverse an improvement. Metrics are assumed
//! cost-like (seconds — bigger is worse), matching every `bench::Report`
//! this crate emits. Columns named in `--strict-cols a,b` are exempt
//! from that asymmetry: they hold deterministic counts (emitted
//! windows, groups) where *any* change — including a drop the ratio
//! rule would praise as "improved" — is a failure. Exits non-zero when
//! any regression is found, so CI can gate on it. Files recorded at
//! different `HPTMT_BENCH_SCALE`s are refused: their row counts are
//! not comparable.

use anyhow::{bail, Context, Result};
use hptmt::util::cli::Args;
use hptmt::util::json::Json;
use std::collections::BTreeMap;

/// One parsed report: name, scale, header, rows keyed by first cell.
struct ReportFile {
    name: String,
    scale: f64,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

fn load(path: &str) -> Result<ReportFile> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let j = Json::parse(&text).with_context(|| format!("parsing {path}"))?;
    let strs = |key: &str| -> Result<Vec<String>> {
        Ok(j.get(key)?
            .as_arr()?
            .iter()
            .map(|c| c.as_str().map(str::to_string))
            .collect::<Result<_>>()?)
    };
    let rows = j
        .get("rows")?
        .as_arr()?
        .iter()
        .map(|r| {
            r.as_arr()?
                .iter()
                .map(|c| c.as_str().map(str::to_string))
                .collect::<Result<Vec<String>>>()
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(ReportFile {
        name: j.get("name")?.as_str()?.to_string(),
        scale: j.get("scale")?.as_f64()?,
        header: strs("header")?,
        rows,
    })
}

/// Parse a report cell as a number, tolerating unit-ish suffixes the
/// reports use ("1.23x", "45%", "0.5s").
fn parse_numeric(cell: &str) -> Option<f64> {
    let t = cell.trim();
    if let Ok(v) = t.parse::<f64>() {
        return Some(v);
    }
    let stripped = t.trim_end_matches(|c: char| c.is_alphabetic() || c == '%');
    if stripped.len() < t.len() {
        stripped.parse::<f64>().ok()
    } else {
        None
    }
}

fn main() -> Result<()> {
    let args = Args::from_env(0);
    let [new_path, base_path] = args.positional() else {
        bail!(
            "usage: bench_diff <bench_out/NAME.json> <BENCH_NAME.json> \
             [--threshold 1.10] [--strict-cols windows,groups]"
        );
    };
    let threshold = args.f64_or("threshold", 1.10)?;
    if threshold <= 1.0 {
        bail!("--threshold must be > 1.0, got {threshold}");
    }
    let strict_cols: Vec<String> = args
        .get("strict-cols")
        .map(|s| s.split(',').map(|c| c.trim().to_string()).filter(|c| !c.is_empty()).collect())
        .unwrap_or_default();

    let new = load(new_path)?;
    let base = load(base_path)?;
    if new.name != base.name {
        bail!("bench name mismatch: {:?} vs {:?} — not the same trajectory", new.name, base.name);
    }
    if new.scale != base.scale {
        bail!(
            "scale mismatch: {} vs {} — runs at different HPTMT_BENCH_SCALE are not comparable",
            new.scale,
            base.scale
        );
    }
    if new.header != base.header {
        bail!("header mismatch: {:?} vs {:?} — schema changed, rebaseline", new.header, base.header);
    }

    let key_of = |row: &[String]| row.first().cloned().unwrap_or_default();
    let base_rows: BTreeMap<String, &Vec<String>> =
        base.rows.iter().map(|r| (key_of(r), r)).collect();

    println!(
        "== bench_diff {} (threshold {threshold:.2}x, scale {}) ==",
        new.name, new.scale
    );
    let mut regressions = 0usize;
    let mut compared = 0usize;
    for row in &new.rows {
        let key = key_of(row);
        let Some(old) = base_rows.get(&key) else {
            println!("  {key:<24} NEW ROW (no baseline)");
            continue;
        };
        for (c, col) in new.header.iter().enumerate().skip(1) {
            if strict_cols.iter().any(|s| s == col) {
                // Deterministic cell: any change is a failure — drops
                // included (fewer emitted windows is lost coverage, not
                // an improvement), and so is a cell that went missing
                // or stopped parsing as the baseline's text.
                compared += 1;
                let (nv, ov) = (row.get(c), old.get(c));
                let flag = match (nv, ov) {
                    (Some(n), Some(o)) if n == o => "ok",
                    _ => {
                        regressions += 1;
                        "CHANGED (strict)"
                    }
                };
                println!(
                    "  {key:<24} {col:<16} {:>12} -> {:>12}  {:>7}  {flag}",
                    ov.map_or("<missing>", String::as_str),
                    nv.map_or("<missing>", String::as_str),
                    "exact"
                );
                continue;
            }
            let (Some(n), Some(o)) = (
                row.get(c).and_then(|s| parse_numeric(s)),
                old.get(c).and_then(|s| parse_numeric(s)),
            ) else {
                continue; // non-numeric cell (labels, notes)
            };
            compared += 1;
            if o <= 0.0 {
                continue; // zero/negative baselines have no meaningful ratio
            }
            let ratio = n / o;
            let flag = if ratio > threshold {
                regressions += 1;
                "REGRESSION"
            } else if ratio < 1.0 / threshold {
                "improved"
            } else {
                "ok"
            };
            println!("  {key:<24} {col:<16} {o:>12.4} -> {n:>12.4}  {ratio:>6.2}x  {flag}");
        }
    }
    // Baseline rows that vanished from the new run are coverage loss,
    // not a pass: count them as failures so a renamed/dropped series
    // cannot silently bypass the gate. (New rows are fine — they gain
    // a baseline when BENCH_*.json is next refreshed.)
    let mut missing = 0usize;
    for row in &base.rows {
        let key = key_of(row);
        if !new.rows.iter().any(|r| key_of(r) == key) {
            missing += 1;
            println!("  {key:<24} MISSING (present in baseline only)");
        }
    }
    if compared == 0 && !base.rows.is_empty() {
        bail!("no numeric cells compared against a non-empty baseline — nothing was checked");
    }
    if regressions > 0 || missing > 0 {
        println!("{regressions} regression(s) beyond {threshold:.2}x, {missing} missing row(s)");
        std::process::exit(1);
    }
    println!("no regressions beyond {threshold:.2}x");
    Ok(())
}
